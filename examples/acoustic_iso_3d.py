"""Acoustic-ISO seismic propagation (the paper's §6.2 production workload):
25-point star stencil, 8th order in space, 2nd in time, PML boundaries,
Ricker source — runnable single-device or domain-decomposed over a mesh.

    PYTHONPATH=src python examples/acoustic_iso_3d.py                # xla
    PYTHONPATH=src python examples/acoustic_iso_3d.py --template f4  # pallas
    PYTHONPATH=src python examples/acoustic_iso_3d.py --distributed  # 8 fake
                                                                     # devices
The distributed form re-execs itself with
XLA_FLAGS=--xla_force_host_platform_device_count=8 and decomposes the
domain (data, model) with ppermute halo exchange + interior/boundary
overlap (DESIGN.md §6).
"""
import argparse
import os
import subprocess
import sys
import time

import numpy as np


def run(args):
    import jax
    from repro.core import acoustic, dsl as st

    shape = tuple(args.shape)
    if args.distributed:
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        backend = st.distributed(grid_axes=("data", "model", None),
                                 overlap=True)
    else:
        mesh = None
        backend = (st.pallas(template=args.template)
                   if args.template else st.xla())

    t0 = time.perf_counter()
    p, prof = acoustic.run(shape=shape, iters=args.iters, backend=backend,
                           mesh=mesh, pml_width=args.pml,
                           fuse_steps=args.fuse)
    wall = time.perf_counter() - t0
    w = np.asarray(p.interior)
    pts = np.prod(shape) * args.iters
    print(f"grid {shape} × {args.iters} steps: {wall:.2f}s "
          f"({pts / wall / 1e6:.1f} Mpoints/s)")
    print(f"profile: {({k: round(v, 3) for k, v in prof.items()})}")
    print(f"wavefield energy {float((w ** 2).sum()):.4e}, "
          f"max |p| {float(np.abs(w).max()):.4e}")
    assert np.isfinite(w).all()
    # PML sanity: boundary energy should be tiny vs interior energy
    c = args.pml
    inner = w[c:-c, c:-c, c:-c]
    shell = float((w ** 2).sum() - (inner ** 2).sum())
    print(f"PML shell energy fraction: {shell / float((w**2).sum()):.3e}")
    print("OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", type=int, nargs=3, default=[48, 48, 48])
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--pml", type=int, default=8)
    ap.add_argument("--template", default=None,
                    choices=[None, "gmem", "smem", "f4", "shift", "unroll",
                             "semi"])
    ap.add_argument("--fuse", type=int, default=None, metavar="K",
                    help="fused time stepping: run K steps per compiled "
                         "program (source injected at window boundaries)")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--_child", action="store_true")
    args = ap.parse_args()

    if args.distributed and not args._child:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env.setdefault("PYTHONPATH", os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src"))
        sys.exit(subprocess.call(
            [sys.executable, os.path.abspath(__file__), "--_child",
             "--distributed", "--iters", str(args.iters), "--pml",
             str(args.pml), "--shape", *map(str, args.shape)]
            + (["--fuse", str(args.fuse)] if args.fuse else []), env=env))
    run(args)


if __name__ == "__main__":
    main()
