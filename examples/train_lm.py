"""End-to-end training driver: trains a ~100M-param dense LM for a few
hundred steps with the full substrate — synthetic deterministic data,
AdamW + warmup-cosine, microbatched grad accumulation, checkpoint/restart,
straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --preset smoke   # ~1 min CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # the real
        # ~100M config, a few hundred steps; sized for a single accelerator
        # or a small mesh — on CPU expect hours, on TPU minutes.

The production-scale path (assigned archs, pod meshes) is
``python -m repro.launch.train --preset full``.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, register
from repro.configs.shapes import ShapeSpec
from repro.train import data, fault_tolerance, optimizer, train_loop

# ~100M dense transformer (GPT-2-medium-ish, swiglu/rope/rmsnorm)
LM_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=2048, vocab=32768, act="swiglu", remat=False,
    scan_layers=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.preset == "smoke":
        cfg = dataclasses.replace(
            LM_100M, name="lm-smoke", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=4, d_ff=256, vocab=512)
        steps = args.steps or 60
        shape = ShapeSpec("train", "train", seq_len=64, global_batch=8)
    else:
        cfg = LM_100M
        steps = args.steps or 300
        shape = ShapeSpec("train", "train", seq_len=512, global_batch=16)

    from repro.models import api
    print(f"{cfg.name}: {api.param_count(cfg) / 1e6:.1f}M params, "
          f"{steps} steps @ {shape.global_batch}×{shape.seq_len}")

    batch_fn = data.make_batch_fn(cfg, shape, seed=0)
    tc = train_loop.TrainConfig(
        opt=optimizer.OptConfig(lr=3e-4, warmup_steps=20, total_steps=steps),
        n_microbatches=args.microbatches)
    step_jit = jax.jit(train_loop.make_train_step(cfg, tc),
                       donate_argnums=(0,))

    def init_fn():
        return train_loop.init_state(cfg, jax.random.PRNGKey(0))

    losses = []

    def one(state, step):
        state, m = step_jit(state, {k: jnp.asarray(v)
                                    for k, v in batch_fn(step).items()})
        loss = float(m["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == steps - 1:
            print(f"step {step:4d}  loss {loss:7.4f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)
        return state

    if args.ckpt_dir:
        wd = fault_tolerance.Watchdog()
        fault_tolerance.run_with_restarts(
            init_fn=init_fn, step_fn=one, n_steps=steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=50, watchdog=wd)
    else:
        state = init_fn()
        for s in range(steps):
            state = one(state, s)

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} → {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training failed to reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
