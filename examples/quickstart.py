"""Quickstart: paper Listing 1 — a star2d4r stencil in the StencilPy DSL.

    PYTHONPATH=src python examples/quickstart.py

Writes the kernel once, runs it on the portable XLA backend and on the
TPU Pallas backend (interpret mode on CPU), and prints the framework's
phase profile (frontend / codegen / compile / kernel — paper Tables 6-8
columns).
"""
import numpy as np

from repro.core import dsl as st


@st.kernel
def kernel_star2d4r(u: st.grid, v: st.grid):
    v.at(0, 0).set(0.25005 * u.at(0, 0)
                   + 0.11111 * (u.at(-4, 0) + u.at(4, 0))
                   + 0.06251 * (u.at(-3, 0) + u.at(3, 0))
                   + 0.06255 * (u.at(-2, 0) + u.at(2, 0))
                   + 0.06245 * (u.at(-1, 0) + u.at(1, 0))
                   + 0.06248 * (u.at(0, -1) + u.at(0, 1))
                   + 0.06243 * (u.at(0, -2) + u.at(0, 2))
                   + 0.06253 * (u.at(0, -3) + u.at(0, 3))
                   - 0.22220 * (u.at(0, -4) + u.at(0, 4)))


@st.target
def target_star2d4r(u: st.grid, v: st.grid, iters: st.i32):
    for _t in range(iters):
        st.map(e=u.shape)(kernel_star2d4r)(u, v)
        (u.data, v.data) = (v.data, u.data)


def main():
    print(kernel_star2d4r)          # parsed stencil info (shape/order/FLOPs)

    u = st.grid(dtype=st.f32, shape=(256, 256), order=4).randomize(0)
    v = st.grid(dtype=st.f32, shape=(256, 256), order=4)

    # portable XLA backend
    res = st.launch(backend=st.xla())(target_star2d4r)(u, v, 50)
    ref = np.asarray(u.interior)
    print("xla profile:", {k: round(t, 4) for k, t in res.profile.items()})

    # TPU Pallas backend (paper's st.cuda(...) Listing-1 form also works)
    u2 = st.grid(dtype=st.f32, shape=(256, 256), order=4).randomize(0)
    v2 = st.grid(dtype=st.f32, shape=(256, 256), order=4)
    res2 = st.launch(backend=st.cuda(computeCapability="9.0",
                                     threadsPerBlock=(8, 128),
                                     template="gmem"))(
        target_star2d4r)(u2, v2, 50)
    got = np.asarray(u2.interior)
    print("pallas profile:", {k: round(t, 4) for k, t in res2.profile.items()})
    err = float(np.abs(got - ref).max())
    scale = max(1.0, float(np.abs(ref).max()))
    print(f"max |pallas - xla| = {err:.3e} (relative {err / scale:.3e})")
    # this stencil amplifies oscillatory modes (paper's own coefficients),
    # so compare at fp32-relative accuracy
    assert err / scale < 1e-5

    # fused time loop: the same 50 steps traced once and executed as a
    # single compiled program (st.timeloop) — one host sync total instead
    # of one per step
    u3 = st.grid(dtype=st.f32, shape=(256, 256), order=4).randomize(0)
    v3 = st.grid(dtype=st.f32, shape=(256, 256), order=4)

    @st.target
    def target_fused(u: st.grid, v: st.grid, iters: st.i32):
        return st.timeloop(iters, swap=("v", "u"))(kernel_star2d4r)(u, v)

    res3 = st.launch(backend=st.xla())(target_fused)(u3, v3, 50)
    tl = res3.value
    err3 = float(np.abs(np.asarray(u3.interior) - ref).max())
    print(f"fused timeloop: {tl.steps} steps in {tl.windows} window(s), "
          f"{tl.steps_per_s:.0f} steps/s, max |fused - per-step| = {err3:.3e}")
    assert err3 / scale < 1e-6
    print("OK")


if __name__ == "__main__":
    main()
