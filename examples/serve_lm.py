"""Serving example: batched greedy/temperature generation over every cache
family — full KV (granite), SWA rolling buffer (mixtral), recurrent state
(xlstm), encoder-decoder (whisper).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serving.serve_loop import BatchServer, GenConfig, Generator


def decoder_demo(name, max_new=8):
    cfg = configs.tiny(configs.get(name))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    server = BatchServer(cfg, params, batch_size=4,
                         gen=GenConfig(max_new_tokens=max_new))
    for _ in range(6):
        server.submit(rng.integers(0, cfg.vocab, int(rng.integers(4, 12))),
                      max_new)
    t0 = time.perf_counter()
    done = server.run_until_drained()
    dt = time.perf_counter() - t0
    n = sum(len(r.result) for r in done.values())
    print(f"{name:20s} ({cfg.family}): {len(done)} reqs, {n} tokens, "
          f"{n / dt:6.1f} tok/s")


def whisper_demo(max_new=8):
    cfg = configs.tiny(configs.get("whisper-small"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    gen = Generator(cfg, params, GenConfig(max_new_tokens=max_new))
    prompts = rng.integers(0, cfg.vocab, (2, 4)).astype(np.int32)
    frames = rng.standard_normal((2, 16, cfg.d_model)).astype(np.float32)
    t0 = time.perf_counter()
    out = gen.generate(prompts, frame_embeds=frames)
    dt = time.perf_counter() - t0
    print(f"{'whisper-small':20s} (audio): transcribed 2 streams → "
          f"{out.shape} in {dt:.1f}s")


def main():
    for name in ("granite-8b", "mixtral-8x7b", "xlstm-1.3b",
                 "recurrentgemma-9b"):
        decoder_demo(name)
    whisper_demo()
    print("OK")


if __name__ == "__main__":
    main()
