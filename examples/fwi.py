"""Full-waveform inversion through the differentiable fused timeloop.

The inversion loop Devito treats as the point of a stencil DSL: propagate
a source through a *guessed* velocity model with the 2-D acoustic leapfrog

    p_next = 2·p1 − p0 + (vp²·dt²)·Δp1

compare the resulting wavefield against data recorded in the *true*
model, and descend the misfit gradient — obtained by ``jax.grad``
straight through ``st.differentiable_timeloop`` (checkpointed O(√T)
adjoint, ``core/adjoint.py``) — with the repo's own AdamW
(``train/optimizer.py``, which must handle a bare velocity-grid parameter
tree).  The "observed" data come from the same propagator run on the true
model (an inversion crime, but exactly what validates the adjoint):

    PYTHONPATH=src python examples/fwi.py            # full inversion
    PYTHONPATH=src python examples/fwi.py --smoke    # CI: tiny + short
    PYTHONPATH=src python examples/fwi.py --smoke --mesh 4
                                       # same inversion, domain sharded
                                       # over 4 forced host devices

Full mode asserts the final misfit falls below 10% of the initial
misfit; smoke mode (a few iterations on a tiny grid) asserts it
decreases at all.  ``--mesh N`` decomposes the domain's first axis over
N devices (forcing N host devices when the platform has fewer) and runs
the identical forward + adjoint through the shard_mapped distributed
engine — gradients reach the sharded velocity model without gathering
the wavefield.
"""
import argparse
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, few iterations (CI job)")
    ap.add_argument("--n", type=int, default=None, help="interior extent")
    ap.add_argument("--steps", type=int, default=None,
                    help="propagation time steps per shot")
    ap.add_argument("--iters", type=int, default=None,
                    help="optimizer iterations")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard the domain over N devices (forces N host "
                         "devices if needed) and run the distributed "
                         "forward + adjoint")
    args = ap.parse_args()

    if args.mesh:
        # must precede the first jax import; forced host devices let CI
        # exercise the mesh path on one CPU process
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={args.mesh}")

    n = args.n or (16 if args.smoke else 48)
    steps = args.steps or (20 if args.smoke else 60)
    iters = args.iters or (8 if args.smoke else 120)
    lr = args.lr or 0.03

    import jax
    import jax.numpy as jnp

    from repro.core import acoustic, dsl as st
    from repro.train import optimizer as opt

    @st.kernel
    def wave2d(p0: st.grid, p1: st.grid, vp2: st.grid, dt: st.f32):
        lap = (p1.at(-1, 0) + p1.at(1, 0) + p1.at(0, -1) + p1.at(0, 1)
               - 4.0 * p1.at(0, 0))
        p0.at(0, 0).set(2.0 * p1.at(0, 0) - p0.at(0, 0)
                        + vp2.at(0, 0) * dt * dt * lap)

    shape = (n, n)
    dt = 0.35                                    # CFL-stable for vp ≤ 1.4
    src = (2, n // 2)                            # shot near the surface

    def between(t, grids):
        # Ricker source into the newest buffer (after the swap, "p1") —
        # pure jnp on g.data, so the hook is traceable and differentiable
        g = grids["p1"]
        idx = (g.order + src[0], g.order + src[1])
        g.data = g.data.at[idx].add(
            acoustic.source_wavelet(t, f0=0.06, t0=10))

    # true model: constant background + a fast inclusion to recover
    vp2_true = np.full(shape, 1.0, np.float32)
    yy, xx = np.mgrid[0:n, 0:n]
    blob = ((yy - n // 2) ** 2 + (xx - n // 2) ** 2) < (n // 6) ** 2
    vp2_true[blob] = 1.69                        # vp 1.0 → 1.3 inside

    def grids(vp2_interior):
        p0 = st.grid(st.f32, shape, order=1)
        p1 = st.grid(st.f32, shape, order=1)
        c = st.grid(st.f32, shape, order=1)
        c.interior = vp2_interior
        return p0, p1, c

    backend = mesh = None
    if args.mesh:
        if n % args.mesh:
            raise SystemExit(f"--mesh {args.mesh} must divide n={n}")
        mesh = jax.make_mesh((args.mesh,), ("data",))
        backend = st.distributed(grid_axes=("data", None))

    p0, p1, c = grids(vp2_true)
    # fuse_steps=1: per-step source cadence; the adjoint thins its
    # checkpoints back to O(√steps) carries (fn.schedule shows the plan)
    fwd = st.differentiable_timeloop(wave2d, p0, p1, c, dt, steps=steps,
                                     swap=("p0", "p1"), fuse_steps=1,
                                     between=between,
                                     backend=backend, mesh=mesh)
    if args.mesh:
        print(f"distributed: axis 0 over {args.mesh} devices "
              f"({jax.device_count()} visible)")
    print(f"grid {shape}, {steps} steps, schedule: "
          f"stride={fwd.schedule['stride']} "
          f"checkpoints={fwd.schedule['checkpoints']} "
          f"of {len(fwd.schedule['windows'])} windows")

    observed = fwd()                             # data in the true model
    d_obs = {g: observed[g] for g in ("p0", "p1")}

    def misfit(vp2_interior):
        arrays = dict(fwd.arrays)
        arrays["vp2"] = arrays["vp2"].at[1:-1, 1:-1].set(vp2_interior)
        out = fwd(arrays, fwd.scalars)
        return 0.5 * sum(jnp.sum((out[g] - d_obs[g]) ** 2)
                         for g in ("p0", "p1"))

    cfg = opt.OptConfig(lr=lr, warmup_steps=5, total_steps=iters,
                        min_lr_ratio=0.3, weight_decay=0.1, clip_norm=10.0)
    params = jnp.full(shape, 1.0, jnp.float32)   # start from background
    state = opt.init(params)

    @jax.jit
    def update(params, state, step):
        loss, g = jax.value_and_grad(misfit)(params)
        params, state, metrics = opt.apply(cfg, params, g, state, step)
        return params, state, loss, metrics

    loss0 = None
    t0 = time.perf_counter()
    for i in range(iters):
        params, state, loss, metrics = update(params, state, jnp.int32(i))
        loss = float(loss)
        if loss0 is None:
            loss0 = loss
        if i % 10 == 0 or i == iters - 1:
            print(f"iter {i:4d}  misfit {loss:.6e}  "
                  f"({loss / loss0:6.1%} of initial)  "
                  f"|grad| {float(metrics['grad_norm']):.3e}")
    wall = time.perf_counter() - t0
    print(f"{iters} iterations in {wall:.1f}s")

    model_err0 = float(np.abs(vp2_true - 1.0).mean())
    model_err = float(jnp.abs(jnp.asarray(vp2_true) - params).mean())
    print(f"model error {model_err:.4f} (initial {model_err0:.4f})")

    if args.smoke:
        assert loss < loss0, f"misfit did not decrease: {loss0} -> {loss}"
        print(f"OK (smoke): misfit {loss0:.3e} -> {loss:.3e}")
    else:
        assert loss < 0.10 * loss0, \
            f"final misfit {loss:.3e} not < 10% of initial {loss0:.3e}"
        print(f"OK: final misfit {loss / loss0:.1%} of initial")


if __name__ == "__main__":
    main()
