"""Documentation gate for the public engine surface.

Three subcommands, each exiting non-zero on failure so CI can gate on them:

    python tools/check_docs.py docstrings   # public API must be documented
    python tools/check_docs.py links        # intra-repo markdown links resolve
    python tools/check_docs.py doctest      # docstring examples actually run
    python tools/check_docs.py all

``docstrings`` imports the public engine modules (``repro.core.dsl``,
``timeloop``, ``adjoint``, ``autotune``, ``halo``) and walks their public
surface: module-level functions/classes (``__all__`` when defined, else
non-underscore names defined in the module) plus public methods and
properties of those classes.  Anything missing a docstring fails the check
with its qualified name.

``links`` scans every tracked ``*.md`` file for ``[text](target)`` links and
verifies relative targets exist on disk (http/https/mailto and pure
``#anchor`` links are skipped; a ``path#anchor`` target checks only the
path).

``doctest`` runs ``doctest.testmod`` over the same engine modules, so the
usage examples embedded in docstrings are executable claims, not comments.

Run from the repo root with ``PYTHONPATH=src``.
"""
from __future__ import annotations

import argparse
import doctest
import importlib
import inspect
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

PUBLIC_MODULES = (
    "repro.core.dsl",
    "repro.core.timeloop",
    "repro.core.adjoint",
    "repro.core.autotune",
    "repro.core.halo",
)

# Dataclass-generated or inherited plumbing that needs no prose of its own.
SKIP_MEMBERS = {"__init__"}


def _public_toplevel(mod):
    names = getattr(mod, "__all__", None)
    if names is None:
        names = [n for n in vars(mod) if not n.startswith("_")]
    out = []
    for name in names:
        obj = getattr(mod, name, None)
        if obj is None or inspect.ismodule(obj):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        # skip re-exports: only things defined in (or re-exported by a
        # module that claims them via __all__) count
        if getattr(obj, "__module__", None) != mod.__name__ \
                and getattr(mod, "__all__", None) is None:
            continue
        out.append((name, obj))
    return out


def _missing_in_class(cls, qual):
    missing = []
    for name, member in vars(cls).items():
        if name.startswith("_") or name in SKIP_MEMBERS:
            continue
        if isinstance(member, property):
            if not (member.fget and member.fget.__doc__):
                missing.append(f"{qual}.{name} (property)")
        elif inspect.isfunction(member):
            if not member.__doc__:
                missing.append(f"{qual}.{name}()")
        elif inspect.isclass(member):
            if not member.__doc__:
                missing.append(f"{qual}.{name}")
    return missing


def check_docstrings() -> int:
    missing = []
    for modname in PUBLIC_MODULES:
        mod = importlib.import_module(modname)
        if not mod.__doc__:
            missing.append(modname)
        for name, obj in _public_toplevel(mod):
            qual = f"{modname}.{name}"
            if not obj.__doc__:
                missing.append(qual)
            if inspect.isclass(obj) and obj.__module__ == mod.__name__:
                missing.extend(_missing_in_class(obj, qual))
    if missing:
        print("public API entries missing docstrings:")
        for m in sorted(set(missing)):
            print(f"  {m}")
        return 1
    print(f"docstrings: OK ({len(PUBLIC_MODULES)} modules)")
    return 0


_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_CODE_FENCE = re.compile(r"```.*?```", re.S)


def check_links() -> int:
    bad = []
    md_files = [p for p in REPO.rglob("*.md")
                if ".git" not in p.parts and ".pytest_cache" not in p.parts]
    n_links = 0
    for md in md_files:
        text = _CODE_FENCE.sub("", md.read_text())
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            n_links += 1
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                bad.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    if bad:
        print("broken markdown links:")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"links: OK ({n_links} intra-repo links in {len(md_files)} files)")
    return 0


def check_doctests() -> int:
    failures = attempted = 0
    for modname in PUBLIC_MODULES:
        mod = importlib.import_module(modname)
        res = doctest.testmod(mod, verbose=False)
        failures += res.failed
        attempted += res.attempted
    if failures:
        print(f"doctest: {failures} failure(s) of {attempted}")
        return 1
    print(f"doctest: OK ({attempted} examples)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("check", choices=("docstrings", "links", "doctest", "all"))
    ns = ap.parse_args(argv)
    checks = {"docstrings": [check_docstrings], "links": [check_links],
              "doctest": [check_doctests],
              "all": [check_docstrings, check_links, check_doctests]}
    return max(c() for c in checks[ns.check])


if __name__ == "__main__":
    sys.exit(main())
