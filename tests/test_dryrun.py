"""Dry-run machinery tests.

The full 512-device production dry-run runs out-of-process (it must set
XLA_FLAGS before jax init); here we validate the same code path on an
8-device subprocess mesh for a fast arch × every shape kind, plus the HLO
analysis pass on synthetic HLO text.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_lower_compile_all_kinds_small_mesh():
    """train/prefill/decode cells lower+compile on a (4,2) mesh with the
    exact dryrun.lower_cell code path (tiny config, reduced shapes)."""
    out = _run("""
    import dataclasses, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import configs, sharding
    from repro.configs.shapes import ShapeSpec, input_specs
    from repro.models import api
    from repro.serving.serve_loop import make_serve_step
    from repro.train import train_loop
    from repro.train.optimizer import OptConfig

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = configs.tiny(configs.get("granite-8b"))
    for kind, seq, gb in (("train", 64, 8), ("prefill", 64, 8),
                          ("decode", 64, 8)):
        shape = ShapeSpec("t", kind, seq, gb)
        specs = input_specs(cfg, shape)
        pshapes = api.param_shapes(cfg)
        pshard = sharding.param_shardings(cfg, mesh, pshapes)
        if kind == "train":
            tc = train_loop.TrainConfig(opt=OptConfig(), n_microbatches=2)
            with mesh:
                lowered, _ = train_loop.compile_train_step(cfg, tc, mesh,
                                                           specs)
        elif kind == "prefill":
            from repro.launch.dryrun import make_prefill_step
            fn = make_prefill_step(cfg)
            bshard = sharding.batch_shardings(cfg, mesh, specs)
            out_spec = sharding.resolve(("batch", None, "vocab"),
                                        (gb, 1, cfg.vocab), mesh)
            with mesh, sharding.use_activation_mesh(mesh):
                lowered = jax.jit(fn, in_shardings=(pshard, bshard),
                                  out_shardings=NamedSharding(mesh, out_spec)
                                  ).lower(pshapes, specs)
        else:
            step = make_serve_step(cfg)
            cshard = sharding.cache_shardings(cfg, mesh, specs["cache"])
            tshard = NamedSharding(mesh,
                                   sharding.resolve(("batch", None),
                                                    (gb, 1), mesh))
            kshard = sharding.scalar_sharding(mesh)
            with mesh, sharding.use_activation_mesh(mesh):
                lowered = jax.jit(
                    step, in_shardings=(pshard, cshard, tshard, kshard),
                    out_shardings=(tshard, cshard), donate_argnums=(1,)
                ).lower(pshapes, specs["cache"],
                        jax.ShapeDtypeStruct((gb, 1), jnp.int32),
                        jax.ShapeDtypeStruct((2,), jnp.uint32))
        compiled = lowered.compile()
        c = compiled.cost_analysis()
        print("OK", kind, bool(c))
    """)
    assert out.count("OK") == 3


def test_sharded_train_matches_single_device():
    """One sharded train step on a (2,2) mesh == single-device step."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs, sharding
    from repro.configs.shapes import ShapeSpec
    from repro.train import data, train_loop
    from repro.train.optimizer import OptConfig

    cfg = configs.tiny(configs.get("phi3-mini-3.8b"))
    shape = ShapeSpec("t", "train", 32, 8)
    batch = {k: jnp.asarray(v)
             for k, v in data.make_batch_fn(cfg, shape)(0).items()}
    tc = train_loop.TrainConfig(opt=OptConfig(lr=1e-3), n_microbatches=2)
    step = train_loop.make_train_step(cfg, tc)

    state0 = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    ref_state, ref_m = jax.jit(step)(jax.tree.map(jnp.copy, state0), batch)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    st_shard = train_loop.state_shardings(cfg, mesh)
    b_shard = sharding.batch_shardings(
        cfg, mesh, jax.tree.map(lambda x: x, batch))
    with sharding.use_activation_mesh(mesh):
        sh_state, sh_m = jax.jit(
            step, in_shardings=(st_shard, b_shard))(
            jax.device_put(state0, st_shard), batch)
    assert abs(float(ref_m["loss"]) - float(sh_m["loss"])) < 1e-3, \
        (float(ref_m["loss"]), float(sh_m["loss"]))
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(sh_state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)
    print("MATCH")
    """, devices=4)
    assert "MATCH" in out


def test_hlo_analysis_trip_counts():
    from repro.launch import hlo_analysis as H
    hlo = """
HloModule test

%cond.1 (arg.1: (s32[], f32[8,8])) -> pred[] {
  %arg.1 = (s32[], f32[8,8]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %c.1 = s32[] constant(5)
  ROOT %cmp.1 = pred[] compare(%gte.1, %c.1), direction=LT
}

%body.1 (arg.2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg.2 = (s32[], f32[8,8]) parameter(0)
  %gte.2 = s32[] get-tuple-element(%arg.2), index=0
  %gte.3 = f32[8,8] get-tuple-element(%arg.2), index=1
  %ar.1 = f32[8,8] all-reduce(%gte.3), replica_groups=[4,2]<=[8], to_apply=%sum.1
  %dot.1 = f32[8,8] dot(%ar.1, %gte.3), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c.2 = s32[] constant(1)
  %add.1 = s32[] add(%gte.2, %c.2)
  ROOT %t.1 = (s32[], f32[8,8]) tuple(%add.1, %dot.1)
}

%sum.1 (x.1: f32[], y.1: f32[]) -> f32[] {
  %x.1 = f32[] parameter(0)
  %y.1 = f32[] parameter(1)
  ROOT %a.1 = f32[] add(%x.1, %y.1)
}

ENTRY %main (p.1: f32[8,8]) -> (s32[], f32[8,8]) {
  %p.1 = f32[8,8] parameter(0)
  %c.3 = s32[] constant(0)
  %t.2 = (s32[], f32[8,8]) tuple(%c.3, %p.1)
  ROOT %w.1 = (s32[], f32[8,8]) while(%t.2), condition=%cond.1, body=%body.1
}
"""
    st = H.analyze(hlo, 8)
    # 5 trips × one dot of 2·64·8 flops
    assert st.dot_flops == 5 * 2 * 64 * 8, st.dot_flops
    # 5 trips × all-reduce of 256 bytes, group 2: 2·256·(1/2) = 256
    assert st.coll_counts["all-reduce"] == 5
    assert st.coll_bytes["all-reduce"] == 5 * 256.0, st.coll_bytes


def test_baseline_artifacts_complete_if_present():
    """If the production dry-run artifacts exist, every non-skipped cell
    must have compiled ok on both meshes (40 cells - 6 skips = 34 ok per
    mesh)."""
    art = os.path.join(_REPO, "benchmarks", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("no artifacts yet")
    merged = os.path.join(art, "dryrun_baseline.json")
    if os.path.exists(merged):
        records = json.load(open(merged))
    else:
        records = []
        for fn in os.listdir(art):
            if fn.startswith("dryrun_") and fn.endswith(".json"):
                records.extend(json.load(open(os.path.join(art, fn))))
    if not records:
        pytest.skip("no artifacts yet")
    for mesh in ("single", "multi"):
        cells = [r for r in records
                 if r["mesh"] == mesh and r.get("kind") != "stencil"]
        errs = [r for r in cells if r["status"] == "error"]
        assert not errs, [(r["arch"], r["shape"], r["error"]) for r in errs]
        assert sum(r["status"] == "ok" for r in cells) == 34, len(cells)
        assert sum(r["status"] == "skipped" for r in cells) == 6
        stencil = [r for r in records
                   if r["mesh"] == mesh and r.get("kind") == "stencil"]
        assert all(r["status"] == "ok" for r in stencil)
