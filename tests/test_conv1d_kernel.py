"""Causal depthwise conv1d Pallas kernel vs oracle: shape/dtype/width
sweeps + the Griffin integration path (use_pallas_conv)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.conv1d.ref import causal_conv1d_ref


@pytest.mark.parametrize("B,T,W,cw", [
    (2, 32, 16, 4),
    (1, 100, 24, 4),      # ragged T (padding path)
    (3, 16, 128, 2),
    (2, 64, 8, 1),        # pointwise (no history)
    (1, 8, 16, 8),        # cw == T
])
def test_matches_oracle(B, T, W, cw):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, T, W)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((cw, W)), jnp.float32)
    got = causal_conv1d(x, w)
    want = causal_conv1d_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((4, 16)), jnp.bfloat16)
    got = causal_conv1d(x, w)
    want = causal_conv1d_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_causality():
    """Future inputs must not affect past outputs."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 32, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    y1 = causal_conv1d(x, w)
    x2 = x.at[:, 20:].set(123.0)
    y2 = causal_conv1d(x2, w)
    np.testing.assert_allclose(np.asarray(y1[:, :20]),
                               np.asarray(y2[:, :20]), atol=1e-6)


def test_griffin_pallas_conv_path():
    """griffin.causal_conv(use_pallas=True) == jnp-shift path, with and
    without a decode state."""
    from repro.models.griffin import causal_conv
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 24, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((16,)), jnp.float32)

    y_ref, st_ref = causal_conv(x, w, b, use_pallas=False)
    y_pl, st_pl = causal_conv(x, w, b, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_pl), np.asarray(st_ref))

    state = jnp.asarray(rng.standard_normal((2, 3, 16)), jnp.float32)
    y_ref2, _ = causal_conv(x, w, b, state=state, use_pallas=False)
    y_pl2, _ = causal_conv(x, w, b, state=state, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pl2), np.asarray(y_ref2),
                               rtol=1e-5, atol=1e-5)
