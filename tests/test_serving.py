"""Serving tests: serve_step, greedy generation determinism, rolling-window
cache equivalence, batch server wave scheduling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serving.serve_loop import BatchServer, GenConfig, Generator, \
    make_serve_step


def _setup(name="granite-8b", seed=0):
    cfg = dataclasses.replace(configs.tiny(configs.get(name)), remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def test_serve_step_shapes():
    cfg, params = _setup()
    cache = api.init_cache(cfg, 2, 8)
    step = jax.jit(make_serve_step(cfg))
    nxt, cache2 = step(params, cache, jnp.zeros((2, 1), jnp.int32),
                       jnp.zeros((2,), jnp.uint32))
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert int(cache2["pos"]) == 1


def test_greedy_generation_deterministic():
    cfg, params = _setup()
    gen = Generator(cfg, params, GenConfig(max_new_tokens=6))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 5))
    a = gen.generate(prompts.astype(np.int32))
    b = gen.generate(prompts.astype(np.int32))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 11)
    np.testing.assert_array_equal(a[:, :5], prompts)


def test_temperature_sampling_varies():
    cfg, params = _setup()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 4)) \
        .astype(np.int32)
    a = Generator(cfg, params, GenConfig(max_new_tokens=8, temperature=1.0,
                                         seed=1)).generate(prompts)
    b = Generator(cfg, params, GenConfig(max_new_tokens=8, temperature=1.0,
                                         seed=2)).generate(prompts)
    assert (a[:, 4:] != b[:, 4:]).any()


def test_swa_rolling_buffer_matches_full_cache():
    """With a window-w arch, decoding with a w-sized rolling buffer must
    match decoding with a full-length cache (tokens beyond the window are
    masked anyway)."""
    cfg, params = _setup("mixtral-8x7b")
    assert cfg.window is not None
    rng = np.random.default_rng(0)
    T = cfg.window + 12      # run past the window
    toks = rng.integers(0, cfg.vocab, (1, T)).astype(np.int32)

    def run(cache_len):
        cache = api.init_cache(cfg, 1, cache_len)
        step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
        outs = []
        for i in range(T):
            lg, cache = step(params, cache, toks[:, i:i + 1])
            outs.append(np.asarray(lg[:, 0], np.float32))
        return np.stack(outs, 1)

    full = run(T)                  # cache covers everything
    rolled = run(cfg.window)       # rolling buffer = window
    np.testing.assert_allclose(rolled, full, rtol=2e-2, atol=2e-2)
    agree = (rolled.argmax(-1) == full.argmax(-1)).mean()
    assert agree > 0.95


def test_batch_server_waves():
    cfg, params = _setup()
    srv = BatchServer(cfg, params, batch_size=3,
                      gen=GenConfig(max_new_tokens=4))
    rng = np.random.default_rng(0)
    uids = [srv.submit(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))),
                       max_new_tokens=4) for _ in range(7)]
    done = srv.run_until_drained()
    assert sorted(done) == sorted(uids)
    assert all(len(r.result) == 4 for r in done.values())
    assert all(r.done_at >= r.submitted_at for r in done.values())


def test_batch_server_single_compile():
    """Power-of-two context bucketing: a stream of varied prompt lengths
    whose (prompt + max_new) all land in one ctx bucket must share ONE
    compiled decode step across every wave — per-wave recompilation was
    the old behavior this pins against."""
    cfg, params = _setup()
    srv = BatchServer(cfg, params, batch_size=3,
                      gen=GenConfig(max_new_tokens=4))
    rng = np.random.default_rng(1)
    # prompt len 5..12 + max_new 4 -> ctx 9..16: one pow2 bucket (16)
    uids = [srv.submit(rng.integers(0, cfg.vocab, int(rng.integers(5, 13))),
                       max_new_tokens=4) for _ in range(7)]
    done = srv.run_until_drained()
    assert sorted(done) == sorted(uids)
    assert all(len(r.result) == 4 for r in done.values())
    assert srv._generator._step._cache_size() == 1


def test_ssm_constant_state_decode():
    """xLSTM decode state is O(1) — independent of context length."""
    cfg, params = _setup("xlstm-1.3b")
    c1 = api.init_cache(cfg, 1, 0)
    n1 = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(c1))
    assert api.decode_cache_len(cfg, 10 ** 6) == 0
    step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    lg, c2 = step(params, c1, jnp.zeros((1, 1), jnp.int32))
    n2 = sum(int(np.prod(np.asarray(l).shape)) for l in jax.tree.leaves(c2))
    assert n1 == n2
