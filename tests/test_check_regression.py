"""CI benchmark regression guard: the guarded series must be
machine-independent (same-run speedup ratios and the deterministic HBM
model), since the committed baseline and the CI runner are different
machines."""
import importlib.util
import os

_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "check_regression.py")
_spec = importlib.util.spec_from_file_location("check_regression", _PATH)
cr = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(cr)


def _pvm(best_in_top_k=True, within=True, at_most=True):
    return {kernel: {"best_in_top_k": best_in_top_k,
                     "two_stage_within_10pct": within,
                     "measured_at_most_top_k": at_most}
            for kernel in ("star2d1r", "star3d4r")}


def _bench(star_speed, ac_speed, hbm_red, pvm=None,
           fwd_over_grad=0.2, sqrt_bound=True, grad_finite=True):
    return {
        "star2d1r": {"speedup": star_speed,
                     "fused_steps_per_s": 12345.0},
        "acoustic_iso_3d": {"speedup": ac_speed},
        "star2d1r_pallas": {
            "time_block_4": {"hbm_reduction_vs_time_block_1": hbm_red}},
        "predicted_vs_measured": pvm if pvm is not None else _pvm(),
        "gradient_throughput": {
            "star2d1r": {"fwd_over_grad": fwd_over_grad,
                         "grad_steps_per_s": 6789.0,
                         "sqrt_checkpoint_bound": sqrt_bound,
                         "grad_finite": grad_finite}},
    }


def test_guard_uses_only_machine_independent_series():
    """Absolute steps/s must not be guarded: a fresh run on a 10x slower
    machine with identical ratios passes."""
    base = _bench(6.0, 2.4, 1.6)
    fresh = _bench(6.0, 2.4, 1.6)
    fresh["star2d1r"]["fused_steps_per_s"] = 1234.5   # 10x slower runner
    failures, _ = cr.check(base, fresh)
    assert failures == []
    for path, _tol in cr.GUARDED:
        assert "steps_per_s" not in path


def test_guard_fails_on_ratio_regression():
    # fusion degrading to ~the per-step path: speedup 6.0 -> 1.2
    failures, _ = cr.check(_bench(6.0, 2.4, 1.6), _bench(1.2, 2.4, 1.6))
    assert len(failures) == 1 and "star2d1r.speedup" in failures[0]
    # the HBM model is deterministic, so its tolerance is tight
    failures, _ = cr.check(_bench(6.0, 2.4, 1.6), _bench(6.0, 2.4, 1.0))
    assert len(failures) == 1 and "hbm_reduction" in failures[0]


def test_guard_tolerates_cross_machine_noise_and_missing_keys():
    # the swings observed between two runs of the same code must pass
    failures, _ = cr.check(_bench(5.9, 2.4, 1.585),
                           _bench(5.3, 1.7, 1.585))
    assert failures == []
    base = _bench(6.0, 2.4, 1.6)
    del base["acoustic_iso_3d"]
    failures, notes = cr.check(base, _bench(6.0, 2.4, 1.6))
    assert failures == []
    assert any("skip acoustic_iso_3d" in n for n in notes)


def test_guard_threshold_override():
    failures, _ = cr.check(_bench(6.0, 2.4, 1.6), _bench(5.0, 2.4, 1.6),
                           threshold=0.05)
    assert len(failures) == 1 and "star2d1r.speedup" in failures[0]


def test_cost_model_quality_guard_is_absolute():
    """A cost model that misranks the measured-best out of the shortlist
    must fail CI even when every timing ratio is fine — and threshold
    overrides must not relax it."""
    base = _bench(6.0, 2.4, 1.6)
    bad = _bench(6.0, 2.4, 1.6,
                 pvm=_pvm(best_in_top_k=False))
    failures, _ = cr.check(base, bad)
    assert len(failures) == 2   # both kernels
    assert all("best_in_top_k" in f for f in failures)
    failures, _ = cr.check(base, bad, threshold=10.0)
    assert len(failures) == 2   # absolutes never relaxed


def test_cost_model_guard_covers_all_flags():
    base = _bench(6.0, 2.4, 1.6)
    for flag, kw in (("two_stage_within_10pct", {"within": False}),
                     ("measured_at_most_top_k", {"at_most": False})):
        failures, _ = cr.check(base, _bench(6.0, 2.4, 1.6, pvm=_pvm(**kw)))
        assert len(failures) == 2
        assert all(flag in f for f in failures)


def test_missing_predicted_vs_measured_fails():
    """The quality guard must not silently vanish if the benchmark stops
    emitting the section."""
    fresh = _bench(6.0, 2.4, 1.6)
    del fresh["predicted_vs_measured"]
    failures, _ = cr.check(_bench(6.0, 2.4, 1.6), fresh)
    assert len(failures) == 6


def test_gradient_throughput_guard():
    """The adjoint guard: the same-run fwd/grad ratio tolerates noise
    but fails on collapse, and the √T-checkpoint / finite-gradient
    booleans are absolute."""
    base = _bench(6.0, 2.4, 1.6)
    failures, _ = cr.check(base, _bench(6.0, 2.4, 1.6, fwd_over_grad=0.15))
    assert failures == []
    failures, _ = cr.check(base, _bench(6.0, 2.4, 1.6, fwd_over_grad=0.05))
    assert len(failures) == 1 and "fwd_over_grad" in failures[0]
    failures, _ = cr.check(base, _bench(6.0, 2.4, 1.6, sqrt_bound=False),
                           threshold=10.0)   # absolutes never relaxed
    assert len(failures) == 1 and "sqrt_checkpoint_bound" in failures[0]
    failures, _ = cr.check(base, _bench(6.0, 2.4, 1.6, grad_finite=False))
    assert len(failures) == 1 and "grad_finite" in failures[0]


def _dist_bench(speedup=1.6, bytes_w=16384, match=True, pruning=True,
                adj_match=True, fwd_over_grad=0.6, grad_finite=True):
    row = lambda n: {"modeled_collective_bytes_per_window": bytes_w * n,
                     "steps_per_s": 500.0 * n}
    grad_row = lambda n: {"fwd_over_grad": fwd_over_grad,
                          "grad_steps_per_s": 250.0 * n,
                          "grad_finite": grad_finite,
                          "sqrt_checkpoint_bound": True}
    return {
        "fused_vs_per_window": {"speedup": speedup,
                                "fused_steps_per_s": 448.0},
        "scaling": {mode: {str(n): row(n) for n in (1, 2, 4, 8)}
                    for mode in ("strong", "weak")},
        "collective_model": {c: {"match": match}
                             for c in ("w4_d2", "w5_d2", "w6_d3")},
        "predicted_vs_measured_mesh": {
            "best_in_top_k": True,
            "measured_at_most_top_k": True,
            "distributed_pruning_active": pruning,
        },
        "gradient_scaling": {
            "throughput": {str(n): grad_row(n) for n in (1, 2, 4, 8)},
            "adjoint_collective_model": {
                c: {"match": adj_match, "modeled_adjoint_bytes": bytes_w}
                for c in ("w4_d2", "w5_d2", "w6_d3")},
        },
    }


def test_distributed_guard_ratio_and_absolutes():
    failures, _ = cr.check(_dist_bench(), _dist_bench(speedup=1.5))
    assert failures == []          # cross-machine noise passes
    # fusion silently degrading to per-group dispatch fails
    failures, _ = cr.check(_dist_bench(), _dist_bench(speedup=0.7))
    assert len(failures) == 1 and "fused_vs_per_window.speedup" in failures[0]
    # the HLO cross-check and the mesh-tuning booleans are absolute
    failures, _ = cr.check(_dist_bench(), _dist_bench(match=False),
                           threshold=10.0)
    assert len(failures) == 3
    assert all("collective_model" in f for f in failures)
    failures, _ = cr.check(_dist_bench(), _dist_bench(pruning=False))
    assert len(failures) == 1 and "distributed_pruning_active" in failures[0]


def test_distributed_guard_adjoint():
    """The distributed-adjoint rows: same-run fwd/grad ratio guarded like
    a speedup, the backward HLO cross-check and finite-gradient flags
    absolute, the modeled adjoint bytes exact."""
    # cross-machine noise passes; a collapsed backward ratio fails
    failures, _ = cr.check(_dist_bench(), _dist_bench(fwd_over_grad=0.55))
    assert failures == []
    failures, _ = cr.check(_dist_bench(), _dist_bench(fwd_over_grad=0.2))
    assert len(failures) == 1 \
        and "gradient_scaling.throughput.8.fwd_over_grad" in failures[0]
    # the backward-program HLO cross-check is absolute (3 combos)
    failures, _ = cr.check(_dist_bench(), _dist_bench(adj_match=False),
                           threshold=10.0)
    assert len(failures) == 3
    assert all("adjoint_collective_model" in f for f in failures)
    # a non-finite gradient on any sub-mesh size fails
    failures, _ = cr.check(_dist_bench(), _dist_bench(grad_finite=False))
    assert len(failures) == 4
    assert all("grad_finite" in f for f in failures)
    # modeled adjoint bytes are exact: a one-byte drift fails
    fresh = _dist_bench()
    fresh["gradient_scaling"]["adjoint_collective_model"]["w5_d2"][
        "modeled_adjoint_bytes"] += 1
    failures, _ = cr.check(_dist_bench(), fresh)
    assert len(failures) == 1 and "w5_d2" in failures[0]


def test_distributed_guard_exact_modeled_bytes():
    """The modeled collective-bytes series is pure geometry: a one-byte
    drift vs the baseline fails even though every ratio is fine — and
    absolute steps/s is still never guarded."""
    fresh = _dist_bench()
    fresh["scaling"]["strong"]["8"]["steps_per_s"] = 1.0   # 500x slower
    failures, _ = cr.check(_dist_bench(), fresh)
    assert failures == []
    fresh = _dist_bench()
    fresh["scaling"]["weak"]["4"]["modeled_collective_bytes_per_window"] += 1
    failures, _ = cr.check(_dist_bench(), fresh)
    assert len(failures) == 1 and "weak.4" in failures[0]
    assert "exactly" in failures[0]


def test_serve_guard_checks_cold_shortlist():
    base = {"serve_stream": {"batched_vs_serial_speedup": 3.0},
            "autotune_cache": {"warm": {"measured_candidates": 0},
                               "cold": {"measured_at_most_top_k": True}}}
    ok = {"serve_stream": {"batched_vs_serial_speedup": 2.9},
          "autotune_cache": {"warm": {"measured_candidates": 0},
                             "cold": {"measured_at_most_top_k": True}}}
    failures, _ = cr.check(base, ok)
    assert failures == []
    bad = {"serve_stream": {"batched_vs_serial_speedup": 2.9},
           "autotune_cache": {"warm": {"measured_candidates": 0},
                              "cold": {"measured_at_most_top_k": False}}}
    failures, _ = cr.check(base, bad)
    assert len(failures) == 1 and "measured_at_most_top_k" in failures[0]
