"""Leapfrog checkpoint/restore: ``timeloop.run_resilient`` drives the
engine one fusion window per restartable step through
``train.checkpoint`` + ``train.fault_tolerance``.  Window replay is
deterministic (the identical compiled program on the identical carry),
so a run that crashes and restores must be BIT-EXACT with an
uninterrupted one — asserted with ``np.array_equal``, not allclose —
including ``between``-hook timing and a fresh-process resume from an
existing checkpoint directory.  The multi-device distributed variant
lives in tests/test_distributed.py's subprocess harness."""
import numpy as np
import jax
import pytest

from repro.core import dsl as st, suite
from repro.core.timeloop import TimeloopEngine, run_resilient
from repro.train.fault_tolerance import FailureInjector

SHAPE = (12, 10)
STEPS = 7
FUSE = 2


def _engine(backend=None, mesh=None):
    k = suite.get_kernel("star2d1r")
    halos = {g: (k.info.order,) * k.info.ndim for g in k.ir.grid_params}
    return TimeloopEngine(k.ir, halos, SHAPE, backend or st.xla(),
                          swap=suite.swap_pair(k.name), mesh=mesh)


def _inits(seed=0):
    # engine.run consumes the grid's full (halo-padded) arrays
    k = suite.get_kernel("star2d1r")
    gs = {g: st.grid(np.float32, SHAPE, k.info.order).randomize(seed + i)
          for i, g in enumerate(k.ir.grid_params)}
    return {g: np.asarray(v.data) for g, v in gs.items()}


def _between(t, arrays):
    # a mid-run source injection: resilience must replay it at the same
    # window boundary after a restart
    arrays = dict(arrays)
    arrays["u"] = arrays["u"].at[3, 4].add(np.float32(0.25 * t))
    return arrays


def _assert_bit_exact(a, b, label):
    for g in a:
        assert np.array_equal(np.asarray(a[g]), np.asarray(b[g])), \
            f"{label}: grid '{g}' diverged after restore"


def test_resilient_bit_exact_with_injected_failures(tmp_path):
    eng = _engine()
    inits = _inits()
    ref = eng.run(dict(inits), {}, STEPS, FUSE, _between)

    got = run_resilient(_engine(), dict(inits), {}, STEPS, FUSE, _between,
                        ckpt_dir=str(tmp_path / "ck"), ckpt_every=1,
                        injector=FailureInjector([1, 3]))
    _assert_bit_exact(got, ref, "injected failures")


def test_resilient_checkpoint_cadence(tmp_path):
    # sparse cadence: a failure between checkpoints rolls back and
    # replays deterministically
    eng = _engine()
    inits = _inits(1)
    ref = eng.run(dict(inits), {}, STEPS, FUSE)
    got = run_resilient(_engine(), dict(inits), {}, STEPS, FUSE,
                        ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                        injector=FailureInjector([3]))
    _assert_bit_exact(got, ref, "sparse cadence")


def test_resume_from_existing_checkpoint_dir(tmp_path):
    """A fresh driver pointed at a populated directory resumes at the
    last window boundary instead of restarting from scratch."""
    ckpt = str(tmp_path / "ck")
    inits = _inits(2)
    # first process: covers windows 0-1 (4 of 8 steps), then "dies"
    run_resilient(_engine(), dict(inits), {}, 4, FUSE, ckpt_dir=ckpt)
    # second process: same directory, full horizon — windows 0-1 restore,
    # 2-3 execute
    got = run_resilient(_engine(), dict(inits), {}, 8, FUSE, ckpt_dir=ckpt)
    ref = _engine().run(dict(inits), {}, 8, FUSE)
    _assert_bit_exact(got, ref, "fresh-process resume")


def test_failures_beyond_budget_raise(tmp_path):
    inits = _inits()
    with pytest.raises(RuntimeError, match="injected node failure"):
        run_resilient(_engine(), dict(inits), {}, STEPS, FUSE,
                      ckpt_dir=str(tmp_path / "ck"), max_failures=1,
                      injector=FailureInjector([0, 1]))


def test_resilient_distributed_single_device(tmp_path):
    """The fused sharded window restores bit-exactly too (single-device
    mesh here; the 4-device run is exercised in test_distributed.py)."""
    mesh = jax.make_mesh((1,), ("data",))
    be = st.distributed(grid_axes=("data", None), time_steps=2)
    inits = _inits(3)
    ref = _engine(be, mesh).run(dict(inits), {}, STEPS, 4)
    got = run_resilient(_engine(be, mesh), dict(inits), {}, STEPS, 4,
                        ckpt_dir=str(tmp_path / "ck"), ckpt_every=1,
                        injector=FailureInjector([1]))
    _assert_bit_exact(got, ref, "distributed fused window")
