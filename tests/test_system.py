"""End-to-end system tests: the acoustic-ISO production workload across
backends, PML decompositions, the autotuner, and paper Listing 1 verbatim.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import acoustic, autotune, dsl as st, regions, suite


def test_acoustic_iso_backends_agree():
    shape = (20, 20, 24)
    ref, _ = acoustic.run(shape=shape, iters=6, backend=st.xla())
    w = np.asarray(ref.interior)
    assert np.isfinite(w).all() and np.abs(w).max() > 1e-6
    for backend in (st.pallas(template="gmem"),
                    st.pallas(template="smem"),
                    st.pallas(template="shift", mem_type="vmem"),
                    st.pallas(template="semi")):
        got, _ = acoustic.run(shape=shape, iters=6, backend=backend)
        np.testing.assert_allclose(np.asarray(got.interior), w,
                                   rtol=1e-4, atol=1e-5)


def test_acoustic_wave_propagates_and_pml_absorbs():
    p, _ = acoustic.run(shape=(32, 32, 32), iters=24, pml_width=6)
    w = np.asarray(p.interior)
    c = 6
    inner = w[c:-c, c:-c, c:-c]
    total = float((w ** 2).sum())
    shell = total - float((inner ** 2).sum())
    # energy reached beyond the source but the PML shell holds little
    assert float(np.abs(inner).max()) > 1e-4
    assert shell / total < 0.25, shell / total


def test_pml_region_decompositions_cover_domain():
    shape = (16, 20, 24)
    inner, shells = regions.two_region(shape, 3)
    vol = np.zeros(shape, np.int32)
    for r in [inner] + shells:
        sl = tuple(slice(b, e) for b, e in r)
        vol[sl] += 1
    assert (vol == 1).all()          # exact cover, no overlap
    seven = regions.seven_region(shape, 3)
    assert len(seven) == 7
    vol2 = np.zeros(shape, np.int32)
    for r in seven:
        sl = tuple(slice(b, e) for b, e in r)
        vol2[sl] += 1
    assert (vol2 == 1).all()


def test_two_region_launch_equals_unified():
    """Region-decomposed launches produce the same field as a unified
    whole-domain map (paper §2.2 'dedicated kernels per region')."""
    k = suite.get_kernel("star3d2r")
    shape = (12, 12, 16)
    u = st.grid(dtype=st.f32, shape=shape, order=2).randomize(0)
    v = st.grid(dtype=st.f32, shape=shape, order=2)
    u2, v2 = u.copy(), v.copy()

    @st.target
    def unified(u, v):
        st.map(e=u.shape)(k)(u, v)

    @st.target
    def per_region(u, v):
        inner, shells = regions.two_region(u.shape, 3)
        st.map(begin=[b for b, _ in inner], end=[e for _, e in inner])(k)(u, v)
        for r in shells:
            st.map(begin=[b for b, _ in r], end=[e for _, e in r])(k)(u, v)

    st.launch(backend=st.xla())(unified)(u, v)
    st.launch(backend=st.xla())(per_region)(u2, v2)
    np.testing.assert_allclose(np.asarray(v.interior),
                               np.asarray(v2.interior), atol=1e-6)


def test_autotuner_picks_a_valid_backend():
    k = suite.get_kernel("star2d1r")
    u = st.grid(dtype=st.f32, shape=(32, 128), order=1).randomize(0)
    v = st.grid(dtype=st.f32, shape=(32, 128), order=1)
    space = [st.xla(), st.pallas(template="gmem", block=(8, 128))]
    res = autotune.tune(k, {"u": u, "v": v}, iters=1, space=space)
    assert res.seconds < float("inf")
    assert len(res.trials) == 2

    # tuner result is launchable
    @st.target
    def tgt(u, v):
        st.map(e=u.shape)(k)(u, v)

    st.launch(backend=res.backend)(tgt)(u, v)


def test_paper_listing1_runs_verbatim():
    """Paper Listing 1 (st.cuda backend alias) executes unchanged."""
    @st.kernel
    def kernel_star2d4r(u: st.grid, v: st.grid):
        v.at(0, 0).set(0.25005 * u.at(0, 0)
                       + 0.11111 * (u.at(-4, 0) + u.at(4, 0))
                       + 0.06251 * (u.at(-3, 0) + u.at(3, 0))
                       + 0.06255 * (u.at(-2, 0) + u.at(2, 0))
                       + 0.06245 * (u.at(-1, 0) + u.at(1, 0))
                       + 0.06248 * (u.at(0, -1) + u.at(0, 1))
                       + 0.06243 * (u.at(0, -2) + u.at(0, 2))
                       + 0.06253 * (u.at(0, -3) + u.at(0, 3))
                       - 0.22220 * (u.at(0, -4) + u.at(0, 4)))

    @st.target
    def target_star2d4r(u: st.grid, v: st.grid, it: st.i32):
        for _t in range(it):
            st.map(e=u.shape)(kernel_star2d4r)(u, v)
            (u.data, v.data) = (v.data, u.data)

    u = st.grid(dtype=st.f32, shape=(64, 128), order=4).randomize(0)
    v = st.grid(dtype=st.f32, shape=(64, 128), order=4)
    res = st.launch(backend=st.cuda(computeCapability="9.0",
                                    threadsPerBlock=(16, 128),
                                    template="gmem"))(target_star2d4r)(u, v, 3)
    assert "kernel" in res.profile and "codegen" in res.profile
    assert np.isfinite(np.asarray(u.interior)).all()
