"""Frontend + analysis unit tests: parsing, shape/order inference, errors."""
import pytest

from repro.core import analysis, dsl as st, frontend, ir
from repro.core import suite


def test_star_shape_and_order():
    k = suite.get_kernel("star3d4r")
    assert k.info.shape == "star"
    assert k.info.order == 4
    assert k.info.ndim == 3
    assert k.info.halo == (4, 4, 4)
    assert k.info.n_taps == 25  # the paper's 25-point star
    assert k.info.flops_per_point == 49  # paper Table 4


def test_box_shape():
    k = suite.get_kernel("box2d2r")
    assert k.info.shape == "box"
    assert k.info.n_taps == 25
    assert k.info.flops_per_point == 49  # paper Table 4


@pytest.mark.parametrize("name,flops", [
    ("star2d1r", 9), ("star2d2r", 17), ("star2d3r", 25), ("star2d4r", 33),
    ("star3d1r", 13), ("star3d2r", 25), ("star3d3r", 37), ("star3d4r", 49),
])
def test_paper_table4_star_flops(name, flops):
    assert suite.get_kernel(name).info.flops_per_point == flops


def test_parse_requires_type_hints():
    with pytest.raises(frontend.StencilSyntaxError):
        @st.kernel
        def bad(u, v):  # noqa: ANN001
            v.at(0).set(u.at(0))


def test_parse_rejects_noncenter_write():
    with pytest.raises(frontend.StencilSyntaxError):
        @st.kernel
        def bad(u: st.grid, v: st.grid):
            v.at(1, 0).set(u.at(0, 0))


def test_parse_rejects_dynamic_offsets():
    with pytest.raises(frontend.StencilSyntaxError):
        @st.kernel
        def bad(u: st.grid, v: st.grid, i: st.i32):
            v.at(0, 0).set(u.at(i, 0))


def test_parse_rejects_inconsistent_arity():
    with pytest.raises(frontend.StencilSyntaxError):
        @st.kernel
        def bad(u: st.grid, v: st.grid):
            v.at(0, 0).set(u.at(0, 0, 0))


def test_multi_statement_locals():
    @st.kernel
    def k(u: st.grid, v: st.grid, a: st.f32):
        t = u.at(-1, 0) + u.at(1, 0)
        v.at(0, 0).set(a * t + u.at(0, 0))

    assert k.info.halo == (1, 0)
    assert ("a", "f32") in k.ir.scalar_params


def test_read_after_write_noncenter_rejected():
    with pytest.raises(ValueError, match="non-center read"):
        @st.kernel
        def bad(u: st.grid, v: st.grid):
            v.at(0, 0).set(u.at(0, 0))
            u.at(0, 0).set(v.at(1, 0))


def test_read_after_write_center_allowed():
    @st.kernel
    def ok(u: st.grid, v: st.grid):
        v.at(0, 0).set(u.at(1, 0))
        u.at(0, 0).set(v.at(0, 0) + 1.0)

    assert set(ok.ir.output_grids()) == {"v", "u"}


def test_linearize_simple():
    k = suite.get_kernel("star2d1r")
    stmts = analysis.inline_locals(k.ir)
    terms, const = analysis.linearize(stmts[0].expr)
    assert len(terms) == 5
    assert isinstance(const, ir.Const)


def test_linearize_rejects_product():
    @st.kernel
    def sq(u: st.grid, v: st.grid):
        v.at(0, 0).set(u.at(1, 0) * u.at(-1, 0))

    stmts = analysis.inline_locals(sq.ir)
    with pytest.raises(analysis.NotLinearError):
        analysis.linearize(stmts[0].expr)


def test_linearize_center_fields():
    @st.kernel
    def wv(u: st.grid, vp: st.grid, v: st.grid):
        v.at(0, 0).set(vp.at(0, 0) * (u.at(1, 0) + u.at(-1, 0)) - v.at(0, 0))

    stmts = analysis.inline_locals(wv.ir)
    with pytest.raises(analysis.NotLinearError):
        analysis.linearize(stmts[0].expr)  # strict mode rejects vp·u
    terms, const = analysis.linearize(stmts[0].expr, allow_center_fields=True)
    assert set(terms) == {("u", (1, 0)), ("u", (-1, 0))}
