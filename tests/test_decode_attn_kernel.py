"""Flash decode-attention Pallas kernel vs the pure-jnp oracle: shape /
dtype / block-size / GQA-ratio / masking sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref


def _mk(B, S, H, K, hd, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, K, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, K, hd)), dtype)
    lengths = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("B,S,H,K,hd,bs", [
    (2, 64, 8, 4, 16, 16),        # GQA 2:1 blocks
    (3, 100, 4, 1, 32, 32),       # MQA, ragged S (padding path)
    (1, 33, 16, 16, 8, 8),        # MHA, odd S
    (2, 128, 8, 2, 16, 128),      # single block
    (4, 48, 8, 8, 64, 16),
])
def test_matches_oracle(B, S, H, K, hd, bs):
    q, k, v, lengths = _mk(B, S, H, K, hd)
    got = decode_attention(q, k, v, lengths, block_s=bs)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs():
    q, k, v, lengths = _mk(2, 64, 8, 4, 32, dtype=jnp.bfloat16)
    got = decode_attention(q, k, v, lengths, block_s=32)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_short_lengths_mask_everything_beyond():
    """Entries past `lengths` must not influence the output."""
    q, k, v, _ = _mk(2, 64, 8, 4, 16, seed=1)
    lengths = jnp.asarray([5, 17], jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=16)
    # corrupt the masked region: output must be identical
    k2 = k.at[:, 32:].set(999.0)
    v2 = v.at[:, 32:].set(-999.0)
    got2 = decode_attention(q, k2, v2, lengths, block_s=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                               rtol=1e-6, atol=1e-6)


def test_matches_model_attention_path():
    """Kernel output == the models-layer expanded-SDPA on the same cache
    contents (positions 0..len-1, no window)."""
    from repro.models import layers as L
    B, S, H, K, hd = 2, 32, 8, 4, 16
    q, k, v, _ = _mk(B, S, H, K, hd, seed=2)
    lengths = jnp.full((B,), S, jnp.int32)
    got = decode_attention(q, k, v, lengths, block_s=8)
    mask = jnp.ones((B, 1, 1, S), bool)
    want = L._sdpa(q[:, None], k, v, mask, hd ** -0.5)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
