"""Analytical cost model and the two-stage autotune search: byte
accounting matches the plan geometry, predictions order candidates
sensibly, calibration persists, and pruning measures exactly the
shortlist."""
import json
import math
import os

import pytest

from repro.core import autotune as at
from repro.core import cost_model as cm
from repro.core import dsl as st, suite

F32 = st.f32


def _grids(name="star2d1r", shape=(16, 16)):
    k = suite.get_kernel(name)
    return k, {g: st.grid(F32, shape, k.info.order).randomize(i)
               for i, g in enumerate(k.ir.grid_params)}


def _model():
    """Deterministic model: no probe timing, default rates."""
    return cm.CostModel(calibrate=False)


@pytest.fixture(autouse=True)
def _fresh():
    at.clear_cache()
    at.reset_measure_count()
    cm.reset_default_models()
    yield
    at.clear_cache()
    at.reset_measure_count()
    cm.reset_default_models()


# -- byte accounting -------------------------------------------------------
def test_pallas_step_bytes_match_plan():
    from repro.kernels.stencil import codegen
    k, grids = _grids()
    halos = {n: g.halo for n, g in grids.items()}
    interior = (16, 16)
    backend = st.pallas(template="gmem", time_block=2)
    plan = codegen.plan_pallas(k.ir, halos, interior, backend,
                               swap=("v", "u"))
    sb = _model().step_bytes(k, halos, interior, backend, ("v", "u"), F32)
    assert sb is not None
    per_step, per_window = sb
    assert per_step == plan.hbm_bytes_per_step(4)
    assert per_window == plan.layout_bytes_per_window(4)
    assert per_step > 0 and per_window > 0


def test_infeasible_pallas_plan_costs_inf():
    # star3d4r order-4 halo with an explicit 2-wide block: h=4 > B=2,
    # plan_pallas raises, the model charges inf (never wins, like a
    # measured compile failure)
    k, grids = _grids("star3d4r", shape=(8, 8, 8))
    halos = {n: g.halo for n, g in grids.items()}
    backend = st.pallas(template="gmem", block=(2, 2, 2))
    sb = _model().step_bytes(k, halos, (8, 8, 8), backend, ("v", "u"), F32)
    assert sb is not None and math.isinf(sb[0])
    p = _model().predict(k, grids, backend, 4, 8, ("v", "u"))
    assert math.isinf(p)


def test_xla_step_bytes_positive_and_memoized():
    k, grids = _grids()
    halos = {n: g.halo for n, g in grids.items()}
    model = _model()
    sb = model.step_bytes(k, halos, (16, 16), st.xla(), ("v", "u"), F32)
    assert sb is not None
    assert 0 < sb[0] < float("inf") and sb[1] == 0.0
    assert len(model._bytes_memo) == 1
    again = model.step_bytes(k, halos, (16, 16), st.xla(), ("v", "u"), F32)
    assert again == sb and len(model._bytes_memo) == 1


# -- prediction ------------------------------------------------------------
def test_larger_fuse_predicts_cheaper():
    k, grids = _grids()
    model = _model()
    backend = st.pallas(template="gmem")
    p1 = model.predict(k, grids, backend, 1, 8, ("v", "u"))
    p8 = model.predict(k, grids, backend, 8, 8, ("v", "u"))
    assert p8 < p1  # fewer windows => less layout traffic + overhead


def test_distributed_backend_is_unpredictable():
    # without a mesh the geometry is unknown: no execution class, no price
    k, grids = _grids()
    backend = st.distributed(grid_axes=("data", None))
    assert cm.exec_key(backend) is None
    assert _model().predict(k, grids, backend, 1, 8, ("v", "u")) is None


# -- distributed pricing (mesh-aware) ---------------------------------------
def test_distributed_predict_finite_with_mesh():
    k, grids = _grids(shape=(32, 32))
    backend = st.distributed(grid_axes=("data", None), time_steps=2)
    p = _model().predict(k, grids, backend, 4, 8, ("v", "u"),
                         mesh={"data": 4})
    assert p is not None and math.isfinite(p) and p > 0


def test_distributed_predict_infeasible_geometry_is_inf():
    model = _model()
    # indivisible decomposition
    k, grids = _grids(shape=(30, 30))
    be = st.distributed(grid_axes=("data", None))
    p = model.predict(k, grids, be, 1, 8, ("v", "u"), mesh={"data": 4})
    assert math.isinf(p)
    # k·h deeper than the shard: local 16/8 = 2 < depth 4 × h 1
    k2, grids2 = _grids(shape=(16, 16))
    be2 = st.distributed(grid_axes=("data", None), time_steps=4)
    p2 = model.predict(k2, grids2, be2, 8, 8, ("v", "u"), mesh={"data": 8})
    assert math.isinf(p2)


def test_deeper_skewing_predicts_fewer_group_overheads():
    # equal steps and traffic volume, but time_steps=4 pays 2 exchange
    # groups per window where time_steps=1 pays 8 → cheaper on the link
    k, grids = _grids(shape=(64, 64))
    model = _model()
    be1 = st.distributed(grid_axes=("data", None), time_steps=1)
    be4 = st.distributed(grid_axes=("data", None), time_steps=4)
    p1 = model.predict(k, grids, be1, 8, 8, ("v", "u"), mesh={"data": 4})
    p4 = model.predict(k, grids, be4, 8, 8, ("v", "u"), mesh={"data": 4})
    assert p4 < p1


def test_link_rate_falls_back_without_probeable_mesh():
    # no mesh / shape-only mapping / single-device mesh: nothing a
    # ppermute probe could exercise — fixed default, and nothing cached
    # (a later real-mesh call must still be allowed to probe)
    model = _model()
    assert model.rate_for("link", F32) == cm.DEFAULT_RATES["link"]
    assert model.rate_for("link", F32, mesh={"data": 4}) \
        == cm.DEFAULT_RATES["link"]
    import jax
    mesh1 = jax.make_mesh((1,), ("data",))
    assert model.rate_for("link", F32, mesh=mesh1) \
        == cm.DEFAULT_RATES["link"]
    assert not any(k.startswith("link") for k in model._rates)


def test_link_rate_keyed_by_device_count():
    # a pre-seeded measured rate for the mesh's device count is used and
    # a calibrate=False model never probes past it
    class FakeMesh:
        import numpy as _np
        devices = _np.empty((4,), object)

    seeded = cm.Rate(bytes_per_s=7e9, overhead_s=1e-5)
    model = cm.CostModel(calibrate=False,
                         rates={"link@4/float32": seeded})
    assert model.rate_for("link", F32, mesh=FakeMesh()) == seeded
    # unseeded count falls back to the default (calibrate=False)
    class FakeMesh8:
        import numpy as _np
        devices = _np.empty((8,), object)

    assert model.rate_for("link", F32, mesh=FakeMesh8()) \
        == cm.DEFAULT_RATES["link"]


def test_link_probe_measures_and_persists(tmp_path):
    """Real ppermute ring probe on 4 forced host devices (subprocess, like
    test_distributed.py): the measured rate replaces the default, lands in
    the version-gated roofline JSON, and reloads."""
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = textwrap.dedent(f"""
        import os, numpy as np, jax
        from jax.sharding import Mesh
        from repro.core import cost_model as cm
        d = {str(tmp_path)!r}
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("x",))
        m = cm.CostModel(cache_dir=d, calibrate=True)
        r = m.rate_for("link", np.float32, mesh=mesh)
        assert r != cm.DEFAULT_RATES["link"], r
        assert r.bytes_per_s > 0 and r.overhead_s > 0
        assert "link@4/float32" in m._rates
        # reload from disk without probing
        m2 = cm.CostModel(cache_dir=d, calibrate=False)
        assert m2.rate_for("link", np.float32, mesh=mesh) == r
        files = [f for f in os.listdir(d) if f.startswith("roofline-")]
        assert len(files) == 1 and f"v{{cm.CALIBRATION_VERSION}}" in files[0]
        print("probed", r.bytes_per_s)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "probed" in r.stdout


def test_batch_scales_predicted_traffic():
    k = suite.get_kernel("star2d1r")
    model = _model()
    g1 = {g: st.grid(F32, (16, 16), k.info.order).randomize(i)
          for i, g in enumerate(k.ir.grid_params)}
    g4 = {g: st.grid(F32, (16, 16), k.info.order, batch=4).randomize(i)
          for i, g in enumerate(k.ir.grid_params)}
    p1 = model.predict(k, g1, st.xla(), 8, 8, ("v", "u"))
    p4 = model.predict(k, g4, st.xla(), 8, 8, ("v", "u"))
    assert p4 > p1


# -- calibration persistence ----------------------------------------------
def test_rates_persist_next_to_cache(tmp_path):
    cdir = str(tmp_path)
    r = cm.Rate(bytes_per_s=3e9, overhead_s=5e-5)
    m = cm.CostModel(cache_dir=cdir, calibrate=False)
    m._rates[cm._rate_key("xla", F32)] = r
    m._store_rates()
    files = [f for f in os.listdir(cdir) if f.startswith("roofline-")]
    assert len(files) == 1
    assert f"v{cm.CALIBRATION_VERSION}" in files[0]
    m2 = cm.CostModel(cache_dir=cdir, calibrate=False)
    assert m2.rate_for("xla", F32) == r


def test_stale_calibration_version_ignored(tmp_path):
    cdir = str(tmp_path)
    m = cm.CostModel(cache_dir=cdir, calibrate=False)
    m._rates[cm._rate_key("xla", F32)] = cm.Rate(3e9, 5e-5)
    m._store_rates()
    path = m._cal_path()
    with open(path) as f:
        blob = json.load(f)
    blob["version"] = cm.CALIBRATION_VERSION + 1
    with open(path, "w") as f:
        json.dump(blob, f)
    m2 = cm.CostModel(cache_dir=cdir, calibrate=False)
    assert m2.rate_for("xla", F32) == cm.DEFAULT_RATES["xla"]


# -- two-stage search ------------------------------------------------------
SPACE = [st.xla(), st.pallas(template="gmem")]


def _tune(top_k, model, iters=1, **kw):
    k, grids = _grids()
    return at.tune(k, grids, iters=iters, space=SPACE, swap=("v", "u"),
                   steps=4, fuse_space=(1, 2, 4), time_block_space=(1, 2),
                   top_k=top_k, cost_model=model, **kw)


def test_two_stage_measures_exactly_top_k():
    # space: xla x 3 fuse + gmem x 3 fuse x 2 tb = 9 candidates
    res = _tune(3, _model())
    assert len(res.predicted) == 9
    assert res.measured_candidates == 3
    assert res.pruned_candidates == 6
    assert at.MEASURE_COUNT["measured_candidates"] == 3
    assert at.MEASURE_COUNT["pruned_candidates"] == 6
    assert res.top_k == 3
    # every predicted entry for this space is numeric
    assert all(p is not None for _, _, p in res.predicted)


def test_exhaustive_when_top_k_none():
    res = _tune(None, _model())
    assert res.measured_candidates == 9
    assert res.pruned_candidates == 0
    assert res.top_k is None
    assert len(res.predicted) == 9  # explicit model still predicts all


def test_no_model_no_predictions_when_not_pruning():
    res = _tune(None, None)
    assert res.predicted == []
    assert res.rank_error is None
    assert res.measured_candidates == 9


def test_rank_error_within_shortlist():
    res = _tune(3, _model())
    # the measured best was one of the 3 measured, all drawn from the
    # top of the predicted order
    assert res.rank_error is not None and res.rank_error < 3


def test_two_stage_winner_close_to_exhaustive():
    # iters=3 + a generous bound: µs-scale host timing jitters far more
    # than the model's ranking error on these tiny grids
    model = _model()
    exhaustive = _tune(None, model, iters=3)
    at.clear_cache()
    pruned = _tune(3, model, iters=3)
    ex = {(b.cache_key(), f): dt for b, f, dt in exhaustive.trials}
    in_ex = ex[(pruned.backend.cache_key(), pruned.fuse_steps)]
    assert in_ex <= exhaustive.seconds * 1.5


def test_top_k_zero_rejected():
    with pytest.raises(ValueError):
        _tune(0, _model())


# -- shortlist helper ------------------------------------------------------
def test_shortlist_keeps_cheapest_and_unpredictable():
    preds = [5.0, 1.0, None, 3.0, 2.0, None]
    assert at.shortlist_indices(preds, 2) == [1, 2, 4, 5]
    assert at.shortlist_indices(preds, 1) == [1, 2, 5]
    assert at.shortlist_indices([None, None], 1) == [0, 1]
    assert at.shortlist_indices([], 3) == []


def test_shortlist_tie_break_is_original_order():
    assert at.shortlist_indices([1.0, 1.0, 1.0], 2) == [0, 1]


def test_shortlist_inf_ranks_last():
    preds = [float("inf"), 2.0, 1.0]
    assert at.shortlist_indices(preds, 2) == [1, 2]


# -- mesh-aware tuning ------------------------------------------------------
def _mesh_space():
    return [st.xla(), st.pallas(template="gmem"),
            (st.distributed(grid_axes=("data", None)), 1),
            (st.distributed(grid_axes=("data", None), time_steps=2), 4)]


def test_tune_with_mesh_prunes_distributed_candidates():
    """Acceptance: over a mesh-inclusive space the tuner predicts EVERY
    row (distributed included — the mesh makes them priceable) and
    measures at most top_k, so distributed candidates participate in
    pruning instead of forcing exhaustive measurement."""
    import jax
    k, grids = _grids()
    mesh = jax.make_mesh((1,), ("data",))
    res = at.tune(k, grids, iters=1, space=_mesh_space(), swap=("v", "u"),
                  steps=4, fuse_space=(1,), time_block_space=(1,),
                  top_k=2, cost_model=_model(), mesh=mesh)
    assert len(res.predicted) == 4
    assert all(p is not None for _, _, p in res.predicted)
    assert res.measured_candidates == 2
    assert res.pruned_candidates == 2
    # rank check extends to mesh rows: the winner came from the shortlist
    assert res.rank_error is not None and res.rank_error < 2


def test_mesh_results_skip_disk_cache(tmp_path):
    """Mesh-tuned results stay in-memory: the disk key carries no mesh
    descriptor, so persisting them would leak one mesh's winner into
    every other topology."""
    import jax
    k, grids = _grids()
    mesh = jax.make_mesh((1,), ("data",))
    at.tune(k, grids, iters=1, space=[st.xla()], swap=("v", "u"),
            steps=2, fuse_space=(1,), top_k=None, cost_model=_model(),
            cache_dir=str(tmp_path), mesh=mesh)
    assert not os.listdir(tmp_path)
    at.clear_cache()
    at.tune(k, grids, iters=1, space=[st.xla()], swap=("v", "u"),
            steps=2, fuse_space=(1,), top_k=None, cost_model=_model(),
            cache_dir=str(tmp_path))
    assert os.listdir(tmp_path)
