"""HaloSpec: the exchange geometry of the distributed runtime as a
device-free object.  Every quantity the fused sharded timeloop depends
on — pad widths under time_block × time_steps composition, neighbor
slabs vs global-boundary zero fill, shrinking compute regions, the
overlap pre-pass decomposition, indivisible-window group splits, and
per-window collective-byte pricing — is asserted here directly on the
spec, no mesh or devices required."""
import pytest

from repro.core.halo import HaloExchange, HaloSpec
from repro.core.timeloop import window_parts

HALOS = {"u": (1, 1), "v": (1, 1), "c": (0, 0)}
SWAP = ("v", "u")


def _spec(depth=1, mesh=4, shape=(16, 12), axes=("data", None), swap=SWAP,
          halos=HALOS):
    return HaloSpec.build(halos, axes, shape, {"data": mesh},
                          depth=depth, swap=swap)


# ---- pad/exchange widths under temporal composition ------------------------
@pytest.mark.parametrize("depth,swap_w,coeff_w", [
    # swap pair k·h_max uniform; coefficients (k−1)·h_max + h_g per axis
    (1, 1, 0),
    (2, 2, 1),
    (3, 3, 2),
])
def test_ext_widths_compose_with_depth(depth, swap_w, coeff_w):
    spec = _spec(depth=depth)
    assert spec.h_max == 1
    for g in SWAP:
        assert spec.ext_of(g) == (swap_w, swap_w)
    assert spec.ext_of("c") == (coeff_w, coeff_w)
    assert spec.padded_shape("v") == (4 + 2 * swap_w, 12 + 2 * swap_w)


def test_ext_mixes_per_grid_halo_with_depth():
    # a wider-stencil grid keeps its own halo in the deepest-shell term
    spec = HaloSpec.build({"u": (2, 2), "v": (2, 2), "w": (1, 0)},
                          ("data", None), (32, 8), {"data": 4},
                          depth=2, swap=("v", "u"))
    assert spec.h_max == 2
    assert spec.ext_of("v") == (4, 4)          # k·h_max
    assert spec.ext_of("w") == (3, 2)          # (k−1)·h_max + h_g per axis


def test_with_depth_rebuilds_same_decomposition():
    deep = _spec(depth=3)
    shallow = deep.with_depth(1)
    assert shallow.local_shape == deep.local_shape
    assert shallow.ext_of("v") == (1, 1)
    assert shallow.depth == 1


# ---- validation ------------------------------------------------------------
def test_indivisible_domain_raises():
    with pytest.raises(ValueError, match="not divisible"):
        _spec(shape=(18, 12))


def test_depth_exceeding_local_extent_raises():
    # local 16/4 = 4; k·h = 5·1 > 4
    with pytest.raises(ValueError, match="exceeds local extent"):
        _spec(depth=5)


def test_depth_without_swap_or_halo_raises():
    with pytest.raises(ValueError, match="requires a swap pair"):
        _spec(depth=2, swap=None)
    with pytest.raises(ValueError, match="nonzero stencil halo"):
        _spec(depth=2, halos={"u": (0, 0), "v": (0, 0)})


def test_unknown_mesh_axis_and_bad_swap_raise():
    with pytest.raises(ValueError, match="unknown mesh axis"):
        HaloSpec.build(HALOS, ("model", None), (16, 12), {"data": 4},
                       swap=SWAP)
    with pytest.raises(ValueError, match="not a grid"):
        _spec(swap=("v", "nope"))


# ---- neighbor slabs vs global zero fill ------------------------------------
def test_exchanged_axes_and_zero_fill():
    spec = _spec(depth=2)
    assert spec.decomposed_axes() == (0,)
    assert spec.exchanged(0) and not spec.exchanged(1)
    # unmapped axis 1 takes zeros at full ext width — the global zero halo
    assert spec.zero_widths("v") == (0, 2)
    assert spec.zero_widths("c") == (0, 1)
    # a size-1 mesh axis has no neighbor: everything becomes zero fill
    solo = _spec(depth=2, mesh=1)
    assert not solo.exchanged(0)
    assert solo.zero_widths("v") == (2, 2)
    assert solo.exchanges() == ()


def test_exchange_slabs_xdsl_geometry():
    spec = _spec(depth=2)
    exs = spec.exchanges(["v"])
    # one decomposed axis × two directions
    assert len(exs) == 2
    lo = next(e for e in exs if e.neighbor < 0)
    hi = next(e for e in exs if e.neighbor > 0)
    for e in (lo, hi):
        assert isinstance(e, HaloExchange)
        assert (e.axis, e.mesh_axis, e.width) == (0, "data", 2)
        # axis 0 is first in pad order → trailing axes at raw local extent
        assert e.size == (2, 12)
    assert lo.offset == (-2, 0) and hi.offset == (4, 0)
    # the slab arrives from the neighbor's matching interior strip
    assert lo.source_area() == ((2, 4), (0, 12))
    assert hi.source_area() == ((0, 2), (0, 12))


def test_slab_sizes_pad_earlier_axes():
    # both axes decomposed: axis-1 slabs move after axis 0 is padded, so
    # their axis-0 extent includes both halos
    spec = HaloSpec.build({"u": (1, 1), "v": (1, 1)}, ("r", "c"), (8, 8),
                          {"r": 2, "c": 2}, depth=1, swap=SWAP)
    by_axis = {}
    for e in spec.exchanges(["v"]):
        by_axis.setdefault(e.axis, []).append(e)
    assert {a: len(v) for a, v in by_axis.items()} == {0: 2, 1: 2}
    assert all(e.size == (1, 4) for e in by_axis[0])
    assert all(e.size == (4 + 2, 1) for e in by_axis[1])


# ---- per-step regions & overlap decomposition ------------------------------
def test_step_regions_shrink_to_interior():
    spec = _spec(depth=3)
    assert spec.step_region(0) == ((-2, 6), (0, 12))
    assert spec.step_region(1) == ((-1, 5), (0, 12))
    assert spec.step_region(2) == ((0, 4), (0, 12))
    with pytest.raises(ValueError, match="outside depth"):
        spec.step_region(3)


def test_overlap_bands_tile_step0_exactly():
    spec = _spec(depth=2, mesh=2)          # local (8, 12), h_max 1
    deep = spec.deep_interior()
    assert deep == ((1, 7), (0, 12))
    bands = spec.boundary_bands()
    assert bands == (((-1, 1), (0, 12)), ((7, 9), (0, 12)))
    # bands + deep interior cover step_region(0) with no gaps
    r0 = spec.step_region(0)
    rows = set(range(*deep[0]))
    for b in bands:
        rows |= set(range(*b[0]))
    assert rows == set(range(*r0[0]))
    assert spec.overlap_feasible()
    # 2·h_max consuming the whole local extent leaves no deep interior
    assert not _spec(mesh=8).overlap_feasible()        # local 2 ≤ 2·h_max
    assert not _spec(mesh=1).overlap_feasible()        # nothing exchanged


# ---- window group splits & collective pricing ------------------------------
@pytest.mark.parametrize("window,depth,groups", [
    (12, 4, ((3, 4),)),
    (10, 4, ((2, 4), (1, 2))),     # indivisible → remainder group
    (10, 3, ((3, 3), (1, 1))),
    (2, 4, None),                  # window below depth: see body
])
def test_group_depths_match_window_parts(window, depth, groups):
    spec = _spec(depth=min(depth, 4))
    if groups is None:
        # build at the clamped depth the lowering would use
        spec = _spec(depth=window)
        assert spec.group_depths(window) == ((1, window),)
        return
    assert spec.group_depths(window) == groups
    # consistency with the engine's window decomposition: same step totals
    assert sum(c * d for c, d in spec.group_depths(window)) == window
    assert sum(window_parts(window, depth)) == window


def test_window_collective_bytes_prices_the_schedule():
    spec = _spec(depth=2)
    item = 4
    # swap round at depth 2: 2 grids × 2 directions × (2 × 12) slabs
    swap_round = spec.exchange_bytes(item, ["u", "v"])
    assert swap_round == 2 * 2 * (2 * 12) * item
    # coefficient round at depth 2: ext_of("c") == (1,1) → (1 × 12) slabs
    coeff_round = spec.exchange_bytes(item, ["c"])
    assert coeff_round == 2 * (1 * 12) * item
    # window of 5 → two depth-2 groups + one depth-1 remainder; coeffs once
    d1 = spec.with_depth(1)
    expect = (coeff_round
              + 2 * swap_round
              + d1.exchange_bytes(item, ["u", "v"]))
    assert spec.window_collective_bytes(5, item) == expect
    # batch scales every slab linearly
    assert spec.window_collective_bytes(5, item, batch=3) == 3 * expect


# ---- adjoint geometry: HaloSpec.transpose ----------------------------------
def test_transpose_is_involution_flipping_reverse():
    spec = _spec(depth=2)
    t = spec.transpose()
    assert t.reverse and not spec.reverse
    assert t.transpose() == spec
    # only the direction flag differs — same pads, shapes, depth
    assert t.local_shape == spec.local_shape
    assert t.depth == spec.depth
    for g in ("u", "v", "c"):
        assert t.ext_of(g) == spec.ext_of(g)


def test_transpose_preserves_collective_bytes():
    # the adjoint exchange moves the SAME slabs the opposite way, so the
    # modeled traffic of a backward window equals the forward window's
    spec = _spec(depth=2)
    t = spec.transpose()
    assert t.exchange_bytes(4) == spec.exchange_bytes(4)
    for w in (1, 4, 5, 10):
        assert (t.window_collective_bytes(w, 4)
                == spec.window_collective_bytes(w, 4))


def test_transpose_reverses_slab_geometry():
    spec = _spec(depth=2)
    fwd = {e.neighbor: e for e in spec.exchanges(["v"])}
    adj = {e.neighbor: e for e in spec.transpose().exchanges(["v"])}
    assert set(fwd) == set(adj) == {-1, +1}
    for nb in (-1, +1):
        # cotangent slabs flow the other way: the adjoint exchange toward
        # neighbor nb lands on the forward exchange-from-nb's source strip
        # and pulls from its destination strip, accumulating (+=) there
        f, a = fwd[-nb], adj[nb]
        assert a.accumulate and not f.accumulate
        assert a.size == f.size

        def dest_area(e):
            return tuple((o, o + s) for o, s in zip(e.offset, e.size))

        assert dest_area(a) == f.source_area()
        assert a.source_area() == dest_area(f)


def test_with_depth_preserves_reverse():
    t = _spec(depth=3).transpose()
    assert t.with_depth(1).reverse
