"""Shape-bucketed serving: the pure admission/pack/unpack functions and
the SimServer end-to-end against per-request serial references."""
import numpy as np
import pytest

from repro.core import dsl as st, suite
from repro.serving.stencil_serve import (SimServer, bucket_key, default_swap,
                                         form_waves, pack_wave, unpack_wave)


def _k():
    return suite.get_kernel("star2d1r")


def _serial(kernel, shape, steps, payload, scalars=None):
    """Per-request reference: one unbatched st.timeloop run."""
    k = suite.get_kernel(kernel) if isinstance(kernel, str) else kernel
    gs = {g: st.grid(st.f32, shape, k.info.order) for g in k.ir.grid_params}
    for g, val in payload.items():
        gs[g].interior = val
    if steps:
        args = [gs[g] for g in k.ir.grid_params] + \
               [float(v) for v in (scalars or {}).values()]
        st.launch(backend=st.xla())(lambda: st.timeloop(
            steps, swap=default_swap(k))(k)(*args))()
    return {g: np.asarray(gs[g].interior) for g in gs}


# ---- pure functions --------------------------------------------------------
def test_bucket_key_pow2_rounding():
    assert bucket_key("star2d1r", (12, 18)) == \
        ("star2d1r", (16, 32), "float32")
    # floor of 8 per axis, mixed sizes in one bucket
    assert bucket_key("star2d1r", (3, 5)) == ("star2d1r", (8, 8), "float32")
    assert bucket_key("star2d1r", (16, 32)) == \
        bucket_key("star2d1r", (9, 17))
    assert bucket_key("star2d1r", (12, 18), "float64")[2] == "float64"


def test_default_swap():
    assert default_swap(_k()) == ("v", "u")

    @st.kernel
    def three(u: st.grid, v: st.grid, c: st.grid):
        v.at(0, 0).set(c.at(0, 0) * u.at(0, 0))
    assert default_swap(three) is None


def test_form_waves():
    reqs = list(range(7))
    waves = form_waves(reqs, 3)
    assert [len(w) for w in waves] == [3, 3, 1]
    assert [x for w in waves for x in w] == reqs
    assert form_waves([], 3) == []


def test_pack_wave_embeds_and_pads():
    k = _k()
    bucket = (16, 16)
    u0 = np.arange(10 * 12, dtype=np.float32).reshape(10, 12)
    srv = SimServer()
    uid = srv.submit("star2d1r", (10, 12), 4, {"u": u0})
    (req,) = srv._queues[bucket_key("star2d1r", (10, 12))]
    arrays, mask, limits = pack_wave(k, bucket, [req], batch_cap=3)
    assert arrays["u"].shape == (3, 18, 18)       # cap x (bucket + 2*order)
    # interior payload lands at the corner, inside the halo offset
    np.testing.assert_array_equal(np.asarray(arrays["u"][0, 1:11, 1:13]), u0)
    assert np.asarray(arrays["u"][0, 0]).max() == 0      # zero halos
    # mask covers exactly the true sub-domain
    m = np.asarray(mask)
    assert m[0, :10, :12].all() and not m[0, 10:, :].any() \
        and not m[0, :, 12:].any()
    # dummy slots: all-zero fields, all-False mask, zero budget
    assert not m[1:].any()
    assert np.asarray(arrays["u"][1:]).max() == 0
    assert list(np.asarray(limits)) == [4, 0, 0]
    assert uid == req.uid


def test_pack_wave_halo_padded_payload():
    k = _k()
    full = np.random.default_rng(0).standard_normal((8, 8)).astype(np.float32)
    srv = SimServer()
    srv.submit("star2d1r", (6, 6), 2, {"u": full})   # 6+2*order = 8
    (req,) = srv._queues[bucket_key("star2d1r", (6, 6))]
    arrays, _, _ = pack_wave(k, (8, 8), [req], batch_cap=1)
    # halo-padded payloads land at the origin, boundary values included
    np.testing.assert_array_equal(np.asarray(arrays["u"][0, :8, :8]), full)


def test_pack_wave_errors():
    k = _k()

    def mk(shape, payload):
        srv = SimServer()
        srv.submit("star2d1r", shape, 1, payload)
        (req,) = next(iter(srv._queues.values()))
        return req

    with pytest.raises(ValueError, match="exceeds cap"):
        pack_wave(k, (8, 8), [mk((4, 4), {})] * 3, batch_cap=2)
    with pytest.raises(ValueError, match="exceeds bucket"):
        pack_wave(k, (8, 8), [mk((9, 4), {})], batch_cap=1)
    with pytest.raises(ValueError, match="payload 'u'"):
        pack_wave(k, (8, 8), [mk((4, 4), {"u": np.zeros((5, 5),
                                                        np.float32)})],
                  batch_cap=1)


def test_unpack_wave_roundtrip():
    k = _k()
    rng = np.random.default_rng(1)
    srv = SimServer()
    shapes = [(5, 7), (8, 8)]
    payloads = [{g: rng.standard_normal(s).astype(np.float32)
                 for g in k.ir.grid_params} for s in shapes]
    for s, p in zip(shapes, payloads):
        srv.submit("star2d1r", s, 0, p)
    reqs = [r for q in srv._queues.values() for r in q]
    arrays, _, _ = pack_wave(k, (8, 8), reqs, batch_cap=4)
    outs = unpack_wave(k, arrays, reqs)
    for p, o in zip(payloads, outs):
        for g in k.ir.grid_params:
            np.testing.assert_array_equal(o[g], p[g])


# ---- end-to-end ------------------------------------------------------------
def test_server_matches_serial_mixed_stream():
    """Mixed shapes/steps across two buckets, incl. a steps=0 request —
    every result equals its own serial small-domain run."""
    rng = np.random.default_rng(2)
    jobs = [((10, 12), 5), ((16, 16), 3), ((9, 14), 0),
            ((10, 12), 7), ((4, 4), 2)]       # buckets (16,16) and (8,8)
    srv = SimServer(batch_cap=3, fuse_window=4)
    uids, refs = [], []
    for shape, steps in jobs:
        u0 = rng.standard_normal(shape).astype(np.float32)
        uids.append(srv.submit("star2d1r", shape, steps, {"u": u0}))
        refs.append(_serial("star2d1r", shape, steps, {"u": u0}))
    done = srv.run_until_drained()
    assert srv.pending() == 0
    assert srv.waves_run == 3                 # (16,16): 3+1 reqs, (8,8): 1
    for uid, ref in zip(uids, refs):
        for g, want in ref.items():
            np.testing.assert_allclose(done[uid].result[g], want,
                                       rtol=1e-5, atol=1e-6, err_msg=g)
        assert done[uid].done_at >= done[uid].submitted_at


def test_server_per_request_scalars():
    @st.kernel
    def damped(u: st.grid, v: st.grid, a: st.f32):
        v.at(0, 0).set(a * u.at(0, 0) + 0.1 * (u.at(-1, 0) + u.at(1, 0)))

    rng = np.random.default_rng(3)
    srv = SimServer(batch_cap=2, fuse_window=2, kernels={"damped": damped})
    uids, refs = [], []
    for a in (0.25, 0.75):
        u0 = rng.standard_normal((6, 6)).astype(np.float32)
        uids.append(srv.submit("damped", (6, 6), 4, {"u": u0},
                               scalars={"a": a}))
        refs.append(_serial(damped, (6, 6), 4, {"u": u0}, {"a": a}))
    done = srv.run_until_drained()
    for uid, ref in zip(uids, refs):
        np.testing.assert_allclose(done[uid].result["v"], ref["v"],
                                   rtol=1e-5, atol=1e-6)
    assert not np.allclose(done[uids[0]].result["v"],
                           done[uids[1]].result["v"])


def test_deadline_and_cap_gating():
    srv = SimServer(batch_cap=2, deadline_s=3600.0)
    srv.submit("star2d1r", (4, 4), 1, {})
    assert srv.step() == []                   # partial wave, deadline far
    assert srv.pending() == 1
    srv.submit("star2d1r", (4, 4), 1, {})
    served = srv.step()                       # cap reached -> ready
    assert len(served) == 2 and srv.pending() == 0
    srv.submit("star2d1r", (4, 4), 1, {})
    assert len(srv.step(force=True)) == 1     # force overrides the deadline
    srv2 = SimServer(batch_cap=8, deadline_s=0.0)
    srv2.submit("star2d1r", (4, 4), 1, {})
    assert len(srv2.step()) == 1              # zero deadline -> immediate


def test_waves_share_one_engine_per_bucket():
    srv = SimServer(batch_cap=2, fuse_window=2)
    for steps in (1, 3, 6, 2, 5):             # varied budgets, one bucket
        srv.submit("star2d1r", (6, 7), steps,
                   {"u": np.ones((6, 7), np.float32)})
    srv.run_until_drained()
    assert srv.waves_run == 3
    assert len(srv._engines) == 1             # one compiled program
    (eng, fuse) = next(iter(srv._engines.values()))
    assert fuse == 2 and eng.batch == 2


def test_submit_validation():
    srv = SimServer()
    with pytest.raises(ValueError, match="2D"):
        srv.submit("star2d1r", (4, 4, 4), 1, {})
    with pytest.raises(ValueError, match="steps"):
        srv.submit("star2d1r", (4, 4), -1, {})
    with pytest.raises(ValueError):
        SimServer(batch_cap=0)


def test_tuned_server_prunes_with_cost_model(tmp_path):
    """Cold-start tuning measures only the tune_top_k shortlist; a warm
    'process' reads the disk entry and measures nothing."""
    from repro.core import autotune as at
    from repro.core import cost_model as cm

    k = suite.get_kernel("star2d1r")
    rng = np.random.default_rng(0)
    payload = {g: rng.standard_normal((12, 18)).astype(np.float32)
               for g in k.ir.grid_params}

    def serve_once():
        at.clear_cache()
        at.reset_measure_count()
        srv = SimServer(batch_cap=2, autotune_cache=str(tmp_path),
                        tune_top_k=2,
                        tune_cost_model=cm.CostModel(calibrate=False))
        srv.submit("star2d1r", (12, 18), 4, payload)
        srv.run_until_drained()
        return (at.MEASURE_COUNT["measured_candidates"],
                at.MEASURE_COUNT["pruned_candidates"])

    cold_measured, cold_pruned = serve_once()
    # fuse space (1,2,4,8,16) at tune_steps=8 dedups to 4 candidates
    assert cold_measured == 2
    assert cold_pruned == 2
    warm_measured, _ = serve_once()
    assert warm_measured == 0


def test_tuned_server_exhaustive_when_top_k_none(tmp_path):
    from repro.core import autotune as at
    from repro.core import cost_model as cm

    at.clear_cache()
    at.reset_measure_count()
    k = suite.get_kernel("star2d1r")
    rng = np.random.default_rng(0)
    payload = {g: rng.standard_normal((12, 18)).astype(np.float32)
               for g in k.ir.grid_params}
    srv = SimServer(batch_cap=2, autotune_cache=str(tmp_path),
                    tune_top_k=None,
                    tune_cost_model=cm.CostModel(calibrate=False))
    srv.submit("star2d1r", (12, 18), 4, payload)
    srv.run_until_drained()
    assert at.MEASURE_COUNT["measured_candidates"] == 4
    assert at.MEASURE_COUNT["pruned_candidates"] == 0
