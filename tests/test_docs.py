"""The documentation gate (tools/check_docs.py) passes and actually bites.

The three subcommands run in-process here; CI also runs them as a
separate docs job.  A sabotage test pins that the docstring walker sees
newly-undocumented public API rather than vacuously passing.
"""
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_docstrings_clean():
    assert check_docs.check_docstrings() == 0


def test_links_clean():
    assert check_docs.check_links() == 0


def test_doctests_clean():
    assert check_docs.check_doctests() == 0


def test_docstring_walker_detects_missing(monkeypatch, capsys):
    """Stripping a public docstring must fail the check (not vacuous)."""
    from repro.core import dsl

    monkeypatch.setattr(dsl.grid.randomize, "__doc__", None)
    assert check_docs.check_docstrings() == 1
    assert "grid.randomize" in capsys.readouterr().out


def test_link_checker_detects_broken(tmp_path, monkeypatch, capsys):
    (tmp_path / "index.md").write_text(
        "see [the guide](missing/guide.md) and [jax](https://jax.dev)")
    monkeypatch.setattr(check_docs, "REPO", tmp_path)
    assert check_docs.check_links() == 1
    assert "missing/guide.md" in capsys.readouterr().out


def test_cli_entrypoint():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py"), "links"],
        cwd=REPO, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


@pytest.mark.parametrize("doc", ["architecture.md", "gradients.md"])
def test_guides_exist_and_linked(doc):
    assert (REPO / "docs" / doc).exists()
    assert f"docs/{doc}" in (REPO / "README.md").read_text()
