"""Persistent autotune cache: disk round-trip, bucket sharing,
invalidation, atomic-file hygiene, and the measured-candidate counter."""
import glob
import json
import os

import pytest

from repro.core import autotune as at
from repro.core import dsl as st, suite

SPACE = [st.xla()]
FUSE = (1, 4)


def _tune(cdir, shape=(12, 18), name="star2d1r", space=SPACE, fuse=FUSE):
    k = suite.get_kernel(name)
    grids = {g: st.grid(st.f32, shape, k.info.order).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    return at.tune(k, grids, iters=1, space=space,
                   swap=suite.swap_pair(name), steps=4, fuse_space=fuse,
                   time_block_space=(1,), cache_dir=str(cdir))


def _measured():
    return at.MEASURE_COUNT["measured_candidates"]


@pytest.fixture(autouse=True)
def _fresh_counters():
    at.clear_cache()
    at.reset_measure_count()
    yield
    at.clear_cache()
    at.reset_measure_count()


def test_round_trip_warm_measures_nothing(tmp_path):
    res = _tune(tmp_path)
    assert _measured() == len(SPACE) * len(FUSE)
    files = glob.glob(str(tmp_path / "tune-*.json"))
    assert len(files) == 1
    # simulate a new process: drop the in-memory layer
    at.clear_cache()
    at.reset_measure_count()
    warm = _tune(tmp_path)
    assert _measured() == 0
    assert warm.fuse_steps == res.fuse_steps
    assert warm.backend.kind == res.backend.kind
    assert len(warm.trials) == len(res.trials)
    # no stray tmp files from the atomic write
    assert not glob.glob(str(tmp_path / "*.tmp"))


def test_same_bucket_different_shape_hits(tmp_path):
    _tune(tmp_path, shape=(12, 18))         # bucket (16, 32)
    at.clear_cache()
    at.reset_measure_count()
    _tune(tmp_path, shape=(9, 17))          # same bucket
    assert _measured() == 0
    at.clear_cache()
    at.reset_measure_count()
    _tune(tmp_path, shape=(20, 20))         # bucket (32, 32) -> cold
    assert _measured() == len(SPACE) * len(FUSE)


def test_config_change_invalidates(tmp_path):
    _tune(tmp_path)
    at.clear_cache()
    at.reset_measure_count()
    _tune(tmp_path, fuse=(1, 2))            # different search space
    assert _measured() > 0
    at.clear_cache()
    at.reset_measure_count()
    _tune(tmp_path, name="star2d2r")        # different kernel fingerprint
    assert _measured() > 0


def test_schema_bump_invalidates(tmp_path):
    _tune(tmp_path)
    (path,) = glob.glob(str(tmp_path / "tune-*.json"))
    with open(path) as f:
        entry = json.load(f)
    entry["schema"] = at.SCHEMA_VERSION + 1
    entry["key"]["schema"] = at.SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(entry, f)
    at.clear_cache()
    at.reset_measure_count()
    _tune(tmp_path)
    assert _measured() == len(SPACE) * len(FUSE)   # stale entry ignored


def test_corrupt_entry_is_a_miss(tmp_path):
    _tune(tmp_path)
    (path,) = glob.glob(str(tmp_path / "tune-*.json"))
    with open(path, "w") as f:
        f.write("{ not json")
    at.clear_cache()
    at.reset_measure_count()
    res = _tune(tmp_path)                   # re-measures, then rewrites
    assert _measured() == len(SPACE) * len(FUSE)
    assert res.fuse_steps in FUSE
    with open(path) as f:
        assert json.load(f)["schema"] == at.SCHEMA_VERSION


def test_clear_disk_cache(tmp_path):
    _tune(tmp_path)
    _tune(tmp_path, shape=(20, 20))
    assert at.clear_disk_cache(str(tmp_path)) == 2
    assert not glob.glob(str(tmp_path / "tune-*.json"))
    assert at.clear_disk_cache(str(tmp_path / "nonexistent")) == 0


def test_env_var_directory(tmp_path, monkeypatch):
    monkeypatch.setenv(at.CACHE_ENV, str(tmp_path))
    assert at.cache_dir_from_env() == str(tmp_path)
    k = suite.get_kernel("star2d1r")
    grids = {g: st.grid(st.f32, (12, 18), k.info.order).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    at.tune(k, grids, iters=1, space=SPACE, swap=("v", "u"), steps=4,
            fuse_space=FUSE, time_block_space=(1,))
    assert len(glob.glob(str(tmp_path / "tune-*.json"))) == 1


def test_fingerprint_and_bucket_helpers():
    k = suite.get_kernel("star2d1r")
    fp = at.kernel_fingerprint(k)
    assert fp == at.kernel_fingerprint(k) and len(fp) == 16
    assert fp != at.kernel_fingerprint(suite.get_kernel("star2d2r"))
    assert at.shape_bucket((12, 18)) == (16, 32)
    assert at.shape_bucket((3, 8, 513)) == (8, 8, 1024)


def test_shape_bucket_edge_cases():
    assert at.shape_bucket(()) == ()                     # 0-d
    assert at.shape_bucket((1, 1)) == (8, 8)             # floor 8
    assert at.shape_bucket((0,)) == (8,)                 # degenerate extent
    assert at.shape_bucket((8,)) == (8,)                 # exact pow2 stays
    assert at.shape_bucket((17, 100, 513)) == (32, 128, 1024)  # odd non-pow2


def test_disk_key_distinguishes_dtype():
    k = suite.get_kernel("star2d1r")

    def key_for(dtype):
        grids = {g: st.grid(dtype, (12, 18), k.info.order)
                 for g in k.ir.grid_params}
        return at._disk_key(k, grids, 1, SPACE, ("v", "u"), 4, FUSE, (1,),
                            3)[0]

    import numpy as np
    assert key_for(np.float32) != key_for(np.float64)


def test_disk_key_includes_top_k_and_calibration():
    from repro.core import cost_model as cm
    k = suite.get_kernel("star2d1r")
    grids = {g: st.grid(st.f32, (12, 18), k.info.order)
             for g in k.ir.grid_params}

    def key_for(top_k):
        return at._disk_key(k, grids, 1, SPACE, ("v", "u"), 4, FUSE, (1,),
                            top_k)
    d3, readable = key_for(3)
    d_none, _ = key_for(None)
    assert d3 != d_none
    assert readable["calibration"] == cm.CALIBRATION_VERSION


def test_purge_stale_removes_old_schema_entries(tmp_path):
    _tune(tmp_path)
    _tune(tmp_path, shape=(20, 20))
    files = sorted(glob.glob(str(tmp_path / "tune-*.json")))
    assert len(files) == 2
    # age one entry to a pre-bump schema and corrupt nothing else
    with open(files[0]) as f:
        entry = json.load(f)
    entry["schema"] = at.SCHEMA_VERSION - 1
    with open(files[0], "w") as f:
        json.dump(entry, f)
    assert at.purge_stale(str(tmp_path)) == 1
    assert glob.glob(str(tmp_path / "tune-*.json")) == [files[1]]
    # unreadable files purge too
    with open(files[1], "w") as f:
        f.write("{ not json")
    assert at.purge_stale(str(tmp_path)) == 1
    assert not glob.glob(str(tmp_path / "tune-*.json"))
    assert at.purge_stale(str(tmp_path / "missing")) == 0


def test_first_touch_purges_then_retunes(tmp_path):
    _tune(tmp_path)
    (path,) = glob.glob(str(tmp_path / "tune-*.json"))
    with open(path) as f:
        entry = json.load(f)
    entry["schema"] = at.SCHEMA_VERSION - 1
    with open(path, "w") as f:
        json.dump(entry, f)
    # a "new process" has not touched this directory yet
    at.clear_cache()
    at.reset_measure_count()
    at._PURGED.discard(str(tmp_path))
    _tune(tmp_path)
    assert _measured() == len(SPACE) * len(FUSE)
    # the stale file was purged, a fresh-schema entry replaced it
    (path2,) = glob.glob(str(tmp_path / "tune-*.json"))
    with open(path2) as f:
        assert json.load(f)["schema"] == at.SCHEMA_VERSION


def test_disk_round_trip_preserves_search_stats(tmp_path):
    from repro.core import cost_model as cm
    k = suite.get_kernel("star2d1r")

    def tune(top_k):
        grids = {g: st.grid(st.f32, (12, 18), k.info.order).randomize(i)
                 for i, g in enumerate(k.ir.grid_params)}
        return at.tune(k, grids, iters=1,
                       space=[st.xla(), st.pallas(template="gmem")],
                       swap=("v", "u"), steps=4, fuse_space=(1, 2, 4),
                       time_block_space=(1, 2), cache_dir=str(tmp_path),
                       top_k=top_k, cost_model=cm.CostModel(calibrate=False))

    cold = tune(3)
    assert cold.pruned_candidates == 6 and cold.measured_candidates == 3
    at.clear_cache()
    at.reset_measure_count()
    warm = tune(3)
    assert _measured() == 0                       # pure disk hit
    assert warm.pruned_candidates == cold.pruned_candidates
    assert warm.measured_candidates == cold.measured_candidates
    assert warm.rank_error == cold.rank_error
    assert warm.top_k == 3
    assert len(warm.predicted) == len(cold.predicted) == 9
    got = [(b.cache_key(), f) for b, f, _ in warm.predicted]
    want = [(b.cache_key(), f) for b, f, _ in cold.predicted]
    assert got == want
