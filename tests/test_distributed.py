"""Distributed stencil runtime tests.

These must see >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps the default single device, as required by the dry-run
contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


CHECK_BODY = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import dsl as st, suite
from repro.kernels.stencil import ref

assert len(jax.devices()) == 8, jax.devices()

def check(name, mesh_shape, axis_names, grid_axes, overlap, inner):
    k = suite.get_kernel(name)
    nd = k.info.ndim
    interior = (32, 32) if nd == 2 else (16, 16, 32)
    mesh = jax.make_mesh(mesh_shape, axis_names)
    u = st.grid(dtype=st.f32, shape=interior, order=k.info.order).randomize(0)
    v = st.grid(dtype=st.f32, shape=interior, order=k.info.order)
    be = st.distributed(grid_axes=grid_axes, overlap=overlap, inner=inner)
    def tgt(u, v):
        for _ in range(3):
            st.map(e=u.shape)(k)(u, v)
            (v, u) = (u, v)
        return u
    got = st.launch(backend=be, mesh=mesh)(tgt)(u, v).value.interior

    u2 = st.grid(dtype=st.f32, shape=interior, order=k.info.order).randomize(0)
    v2 = st.grid(dtype=st.f32, shape=interior, order=k.info.order)
    want = st.launch(backend=st.xla())(tgt)(u2, v2).value.interior
    err = float(jnp.abs(got - want).max())
    assert err < 1e-5, (name, mesh_shape, grid_axes, overlap, err)
    print('OK', name, mesh_shape, grid_axes, 'overlap' if overlap else 'sync')
"""


def test_distributed_1d_decomposition():
    _run_in_subprocess(CHECK_BODY + """
check('star2d2r', (8,), ('data',), ('data', None), False, st.xla())
check('star2d2r', (8,), ('data',), ('data', None), True, st.xla())
""")


def test_distributed_2d_decomposition_box():
    _run_in_subprocess(CHECK_BODY + """
check('box2d1r', (4, 2), ('data', 'model'), ('data', 'model'), False, st.xla())
check('box2d1r', (4, 2), ('data', 'model'), ('data', 'model'), True, st.xla())
""")


def test_distributed_3d_multipod_axes():
    _run_in_subprocess(CHECK_BODY + """
check('star3d2r', (2, 2, 2), ('pod', 'data', 'model'),
      ('pod', 'data', 'model'), True, st.xla())
""")


def test_distributed_with_pallas_inner():
    _run_in_subprocess(CHECK_BODY + """
check('star3d1r', (2, 2), ('data', 'model'), ('data', 'model', None), False,
      st.pallas(template='gmem', block=(8, 8, 128)))
""")


def test_distributed_rejects_bad_divisibility():
    _run_in_subprocess(CHECK_BODY + """
from repro.core import distributed as dist
from jax.sharding import Mesh
k = suite.get_kernel('star2d1r')
mesh = jax.make_mesh((8,), ('data',))
try:
    dist.lower_distributed(k.ir, {'u': (1, 1), 'v': (1, 1)}, (30, 30), None,
                           st.distributed(grid_axes=('data', None)), mesh)
except ValueError as e:
    assert 'not divisible' in str(e)
    print('OK divisibility')
else:
    raise AssertionError('expected ValueError')
""")


def test_timeloop_fused_distributed_matches_per_step():
    """st.timeloop on the distributed backend (one shard_mapped program
    per fusion window: fori_loop over depth-k exchange groups) must match
    the per-step distributed target.  The window is a fuse cadence, not
    an exchange depth — any size works; depth (time_steps × time_block)
    is clamped to the window and to k·h ≤ local extent by HaloSpec."""
    _run_in_subprocess("""
import jax, numpy as np
from repro.core import acoustic, dsl as st

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)

def mk():
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    return p0, p1, vp2, damp, dt

p0, p1, vp2, damp, dt = mk()
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 overlap=False), mesh=mesh)(
    acoustic.acoustic_target)(p0, p1, vp2, damp, dt, 6)
ref0, ref1 = np.asarray(p0.data), np.asarray(p1.data)

for fuse in (1, 2, 3, 6):   # 6-step window = 6 depth-1 groups, ONE program
    q = mk()
    st.launch(backend=st.distributed(grid_axes=("data", "model", None)),
              mesh=mesh, fuse_steps=fuse)(
        lambda *a: st.timeloop(6, swap=("p0", "p1"))(
            acoustic.acoustic_iso_kernel)(*a))(*q[:5])
    err = max(float(np.abs(np.asarray(q[0].data) - ref0).max()),
              float(np.abs(np.asarray(q[1].data) - ref1).max()))
    assert err < 1e-6, (fuse, err)
    print("OK fused-distributed", fuse)
""")


def test_time_skewed_matches_stepwise():
    """Overlapped tiling (time_steps=k, ONE k·h-wide exchange) must equal
    k separately-exchanged steps — including at global boundaries where
    the zero grid-halo is re-imposed between fused steps."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st
from repro.core import distributed as dist

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)  # local (12,16): fits k*h <= 12 for k=3, h=4
k_ir = acoustic.acoustic_iso_kernel.ir
halos = {g: acoustic.acoustic_iso_kernel.info.halo for g in k_ir.grid_params}

for k_steps in (2, 3):
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    arrays = {"p0": p0.data, "p1": p1.data, "vp2": vp2.data,
              "damp": damp.data}
    scal = {"dt": dt}

    be = st.distributed(grid_axes=("data", "model", None),
                        time_steps=k_steps, swap=("p0", "p1"))
    fused = dist.lower_distributed(k_ir, halos, shape, None, be, mesh)
    got = fused(dict(arrays), scal)

    be1 = st.distributed(grid_axes=("data", "model", None), overlap=False)
    step = dist.lower_distributed(k_ir, halos, shape, None, be1, mesh)
    ref = dict(arrays)
    for _ in range(k_steps):
        out = step(ref, scal)
        ref = dict(out, p0=ref["p1"], p1=out["p0"])

    for g in ("p0", "p1"):
        err = float(jnp.abs(got[g] - ref[g]).max())
        assert err < 1e-6, (k_steps, g, err)
    print("OK time-skew", k_steps)
""")


def test_time_skew_composes_with_inner_time_block():
    """Device-level skewing × in-kernel temporal blocking: a pallas inner
    carrying time_block=k_inner widens the exchange to
    time_steps·k_inner·h, and the fused result still equals separately
    exchanged steps.  Also reachable through st.timeloop, whose window
    maps onto (kw / k_inner) skewing groups."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st
from repro.core import distributed as dist

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)  # local (12,16): k_total*h <= 12 for k_total=3, h=4
k_ir = acoustic.acoustic_iso_kernel.ir
halos = {g: acoustic.acoustic_iso_kernel.info.halo for g in k_ir.grid_params}

be1 = st.distributed(grid_axes=("data", "model", None), overlap=False)

for t_steps, k_inner in ((1, 2), (1, 3), (3, 1)):
    k_total = t_steps * k_inner
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    arrays = {"p0": p0.data, "p1": p1.data, "vp2": vp2.data,
              "damp": damp.data}
    scal = {"dt": dt}

    be = st.distributed(grid_axes=("data", "model", None),
                        time_steps=t_steps, swap=("p0", "p1"),
                        inner=st.pallas(time_block=k_inner))
    fused = dist.lower_distributed(k_ir, halos, shape, None, be, mesh)
    got = fused(dict(arrays), scal)

    step = dist.lower_distributed(k_ir, halos, shape, None, be1, mesh)
    ref = dict(arrays)
    for _ in range(k_total):
        out = step(ref, scal)
        ref = dict(out, p0=ref["p1"], p1=out["p0"])

    for g in ("p0", "p1"):
        err = float(jnp.abs(got[g] - ref[g]).max())
        assert err < 1e-6, (t_steps, k_inner, g, err)
    print("OK compose", t_steps, "x", k_inner)

# through the engine: fuse window -> (kw / k_inner) skewing groups
p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
acoustic.inject_source(p1, 0)
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 overlap=False), mesh=mesh)(
    acoustic.acoustic_target)(p0, p1, vp2, damp, dt, 6)
ref0, ref1 = np.asarray(p0.data), np.asarray(p1.data)

q = acoustic.make_fields(shape, pml_width=4)
acoustic.inject_source(q[1], 0)
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 inner=st.pallas(time_block=2)),
          mesh=mesh, fuse_steps=2)(
    lambda *a: st.timeloop(6, swap=("p0", "p1"))(
        acoustic.acoustic_iso_kernel)(*a))(*q[:5])
err = max(float(np.abs(np.asarray(q[0].data) - ref0).max()),
          float(np.abs(np.asarray(q[1].data) - ref1).max()))
assert err < 1e-6, err
print("OK engine-compose")
""")


def test_fused_window_single_program_and_collective_model():
    """The fused window lowering advances W steps in ONE jitted program
    (fori_loop over full-depth groups + unrolled remainder), matches the
    proven per-exchange path, and its compiled HLO moves exactly the
    collective bytes ``HaloSpec.window_collective_bytes`` prices — the
    model the distributed cost model and the regression guard rely on."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st
from repro.core import distributed as dist
from repro.launch import hlo_analysis

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)
k_ir = acoustic.acoustic_iso_kernel.ir

for window, t_steps in ((5, 2), (6, 3), (4, 1)):
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    arrays = {"p0": p0.data, "p1": p1.data, "vp2": vp2.data,
              "damp": damp.data}
    scal = {"dt": jnp.float32(dt)}
    interiors = {g: a[tuple(slice(4, 4 + s) for s in shape)]
                 for g, a in arrays.items()}

    be = st.distributed(grid_axes=("data", "model", None),
                        time_steps=t_steps, swap=("p0", "p1"))
    fn = dist.lower_distributed_window(k_ir, shape, be, mesh,
                                       ("p0", "p1"), window)
    assert fn.depth == t_steps and fn.window == window
    got = fn(dict(arrays), scal)

    # reference: the per-exchange time-skewed path, group by group
    halos = {g: acoustic.acoustic_iso_kernel.info.halo
             for g in k_ir.grid_params}
    ref = dict(arrays)
    for count, d in fn.groups:
        bd = st.distributed(grid_axes=("data", "model", None),
                            time_steps=d, swap=("p0", "p1")) if d > 1 \
            else st.distributed(grid_axes=("data", "model", None),
                                overlap=False)
        g_fn = dist.lower_distributed(k_ir, halos, shape, None, bd, mesh)
        for _ in range(count):
            out = g_fn(ref, scal)
            # the time-skewed path (d > 1) returns post-swap state; the
            # per-step path writes swap[0] and leaves the swap to us
            ref = dict(out, p0=ref["p1"], p1=out["p0"]) if d == 1 else out
    for g in ("p0", "p1"):
        err = float(jnp.abs(got[g] - ref[g]).max())
        assert err < 1e-6, (window, t_steps, g, err)

    # ONE program; its HLO collective traffic == the HaloSpec price
    hlo = fn.jitted.lower(interiors, scal).compile().as_text()
    stats = hlo_analysis.op_stats(hlo, n_devices=8)
    want = fn.spec.window_collective_bytes(window, 4)
    assert stats.collective_bytes == want, (
        window, t_steps, stats.collective_bytes, want)
    print("OK fused-window", window, "depth", t_steps,
          int(stats.collective_bytes), "coll bytes")
""")


def test_distributed_batched_multi_device():
    """Satellite: batched scenarios ride the fused sharded timeloop — a
    leading unsharded batch axis over a real multi-device mesh must equal
    per-scenario distributed runs."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import dsl as st, suite

B, STEPS, FUSE = 3, 6, 3
mesh = jax.make_mesh((4,), ("data",))
k = suite.get_kernel("star2d2r")
shape = (32, 24)
rng = np.random.default_rng(0)
inits = {g: rng.standard_normal((B,) + shape).astype(np.float32)
         for g in k.ir.grid_params}
be = st.distributed(grid_axes=("data", None), time_steps=2)

ser = []
for b in range(B):
    gs = {g: st.grid(st.f32, shape, k.info.order) for g in k.ir.grid_params}
    for g in gs:
        gs[g].interior = inits[g][b]
    st.launch(backend=be, mesh=mesh, fuse_steps=FUSE)(
        lambda *a: st.timeloop(STEPS, swap=suite.swap_pair(k.name))(k)(*a))(
        *gs.values())
    ser.append({g: np.asarray(gs[g].interior) for g in gs})

gb = {g: st.grid(st.f32, shape, k.info.order, batch=B)
      for g in k.ir.grid_params}
for g in gb:
    gb[g].interior = inits[g]
st.launch(backend=be, mesh=mesh, fuse_steps=FUSE)(
    lambda *a: st.timeloop(STEPS, swap=suite.swap_pair(k.name), batch=B)(k)(
        *a))(*gb.values())

for g in gb:
    for b in range(B):
        err = float(np.abs(np.asarray(gb[g].interior)[b] - ser[b][g]).max())
        assert err < 1e-5, (g, b, err)
print("OK batched-distributed 4dev")
""")


def test_resilient_distributed_multi_device(tmp_path):
    """Satellite: checkpoint/restore of the leapfrog carry under the
    fused sharded timeloop is bit-exact across an injected failure on a
    real multi-device mesh."""
    _run_in_subprocess(f"""
import jax, numpy as np
from repro.core import dsl as st, suite
from repro.core.timeloop import TimeloopEngine, run_resilient
from repro.train.fault_tolerance import FailureInjector

mesh = jax.make_mesh((4,), ("data",))
k = suite.get_kernel("star2d1r")
shape = (24, 16)
halos = {{g: (k.info.order,) * k.info.ndim for g in k.ir.grid_params}}
be = st.distributed(grid_axes=("data", None), time_steps=2)

def engine():
    return TimeloopEngine(k.ir, halos, shape, be,
                          swap=suite.swap_pair(k.name), mesh=mesh)

gs = {{g: st.grid(np.float32, shape, k.info.order).randomize(i)
      for i, g in enumerate(k.ir.grid_params)}}
inits = {{g: np.asarray(v.data) for g, v in gs.items()}}

ref = engine().run(dict(inits), {{}}, 7, 4)
got = run_resilient(engine(), dict(inits), {{}}, 7, 4,
                    ckpt_dir={str(tmp_path / 'ck')!r}, ckpt_every=1,
                    injector=FailureInjector([1]))
for g in ref:
    assert np.array_equal(np.asarray(ref[g]), np.asarray(got[g])), g
print("OK resilient-distributed 4dev")
""")
