"""Distributed stencil runtime tests.

These must see >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process keeps the default single device, as required by the dry-run
contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


CHECK_BODY = """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import dsl as st, suite
from repro.kernels.stencil import ref

assert len(jax.devices()) == 8, jax.devices()

def check(name, mesh_shape, axis_names, grid_axes, overlap, inner):
    k = suite.get_kernel(name)
    nd = k.info.ndim
    interior = (32, 32) if nd == 2 else (16, 16, 32)
    mesh = jax.make_mesh(mesh_shape, axis_names)
    u = st.grid(dtype=st.f32, shape=interior, order=k.info.order).randomize(0)
    v = st.grid(dtype=st.f32, shape=interior, order=k.info.order)
    be = st.distributed(grid_axes=grid_axes, overlap=overlap, inner=inner)
    def tgt(u, v):
        for _ in range(3):
            st.map(e=u.shape)(k)(u, v)
            (v, u) = (u, v)
        return u
    got = st.launch(backend=be, mesh=mesh)(tgt)(u, v).value.interior

    u2 = st.grid(dtype=st.f32, shape=interior, order=k.info.order).randomize(0)
    v2 = st.grid(dtype=st.f32, shape=interior, order=k.info.order)
    want = st.launch(backend=st.xla())(tgt)(u2, v2).value.interior
    err = float(jnp.abs(got - want).max())
    assert err < 1e-5, (name, mesh_shape, grid_axes, overlap, err)
    print('OK', name, mesh_shape, grid_axes, 'overlap' if overlap else 'sync')
"""


def test_distributed_1d_decomposition():
    _run_in_subprocess(CHECK_BODY + """
check('star2d2r', (8,), ('data',), ('data', None), False, st.xla())
check('star2d2r', (8,), ('data',), ('data', None), True, st.xla())
""")


def test_distributed_2d_decomposition_box():
    _run_in_subprocess(CHECK_BODY + """
check('box2d1r', (4, 2), ('data', 'model'), ('data', 'model'), False, st.xla())
check('box2d1r', (4, 2), ('data', 'model'), ('data', 'model'), True, st.xla())
""")


def test_distributed_3d_multipod_axes():
    _run_in_subprocess(CHECK_BODY + """
check('star3d2r', (2, 2, 2), ('pod', 'data', 'model'),
      ('pod', 'data', 'model'), True, st.xla())
""")


def test_distributed_with_pallas_inner():
    _run_in_subprocess(CHECK_BODY + """
check('star3d1r', (2, 2), ('data', 'model'), ('data', 'model', None), False,
      st.pallas(template='gmem', block=(8, 8, 128)))
""")


def test_distributed_rejects_bad_divisibility():
    _run_in_subprocess(CHECK_BODY + """
from repro.core import distributed as dist
from jax.sharding import Mesh
k = suite.get_kernel('star2d1r')
mesh = jax.make_mesh((8,), ('data',))
try:
    dist.lower_distributed(k.ir, {'u': (1, 1), 'v': (1, 1)}, (30, 30), None,
                           st.distributed(grid_axes=('data', None)), mesh)
except ValueError as e:
    assert 'not divisible' in str(e)
    print('OK divisibility')
else:
    raise AssertionError('expected ValueError')
""")


def test_timeloop_fused_distributed_matches_per_step():
    """st.timeloop on the distributed backend (fusion window → overlapped
    tiling / time skewing, unifying fuse_steps with time_steps) must match
    the per-step distributed target; oversized windows clamp to k·h ≤
    local extent instead of failing."""
    _run_in_subprocess("""
import jax, numpy as np
from repro.core import acoustic, dsl as st

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)

def mk():
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    return p0, p1, vp2, damp, dt

p0, p1, vp2, damp, dt = mk()
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 overlap=False), mesh=mesh)(
    acoustic.acoustic_target)(p0, p1, vp2, damp, dt, 6)
ref0, ref1 = np.asarray(p0.data), np.asarray(p1.data)

for fuse in (1, 2, 3, 6):   # 6 > max feasible k=3 → clamped, not an error
    q = mk()
    st.launch(backend=st.distributed(grid_axes=("data", "model", None)),
              mesh=mesh, fuse_steps=fuse)(
        lambda *a: st.timeloop(6, swap=("p0", "p1"))(
            acoustic.acoustic_iso_kernel)(*a))(*q[:5])
    err = max(float(np.abs(np.asarray(q[0].data) - ref0).max()),
              float(np.abs(np.asarray(q[1].data) - ref1).max()))
    assert err < 1e-6, (fuse, err)
    print("OK fused-distributed", fuse)
""")


def test_time_skewed_matches_stepwise():
    """Overlapped tiling (time_steps=k, ONE k·h-wide exchange) must equal
    k separately-exchanged steps — including at global boundaries where
    the zero grid-halo is re-imposed between fused steps."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st
from repro.core import distributed as dist

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)  # local (12,16): fits k*h <= 12 for k=3, h=4
k_ir = acoustic.acoustic_iso_kernel.ir
halos = {g: acoustic.acoustic_iso_kernel.info.halo for g in k_ir.grid_params}

for k_steps in (2, 3):
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    arrays = {"p0": p0.data, "p1": p1.data, "vp2": vp2.data,
              "damp": damp.data}
    scal = {"dt": dt}

    be = st.distributed(grid_axes=("data", "model", None),
                        time_steps=k_steps, swap=("p0", "p1"))
    fused = dist.lower_distributed(k_ir, halos, shape, None, be, mesh)
    got = fused(dict(arrays), scal)

    be1 = st.distributed(grid_axes=("data", "model", None), overlap=False)
    step = dist.lower_distributed(k_ir, halos, shape, None, be1, mesh)
    ref = dict(arrays)
    for _ in range(k_steps):
        out = step(ref, scal)
        ref = dict(out, p0=ref["p1"], p1=out["p0"])

    for g in ("p0", "p1"):
        err = float(jnp.abs(got[g] - ref[g]).max())
        assert err < 1e-6, (k_steps, g, err)
    print("OK time-skew", k_steps)
""")


def test_time_skew_composes_with_inner_time_block():
    """Device-level skewing × in-kernel temporal blocking: a pallas inner
    carrying time_block=k_inner widens the exchange to
    time_steps·k_inner·h, and the fused result still equals separately
    exchanged steps.  Also reachable through st.timeloop, whose window
    maps onto (kw / k_inner) skewing groups."""
    _run_in_subprocess("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st
from repro.core import distributed as dist

mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = (48, 32, 24)  # local (12,16): k_total*h <= 12 for k_total=3, h=4
k_ir = acoustic.acoustic_iso_kernel.ir
halos = {g: acoustic.acoustic_iso_kernel.info.halo for g in k_ir.grid_params}

be1 = st.distributed(grid_axes=("data", "model", None), overlap=False)

for t_steps, k_inner in ((1, 2), (1, 3), (3, 1)):
    k_total = t_steps * k_inner
    p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
    acoustic.inject_source(p1, 0)
    arrays = {"p0": p0.data, "p1": p1.data, "vp2": vp2.data,
              "damp": damp.data}
    scal = {"dt": dt}

    be = st.distributed(grid_axes=("data", "model", None),
                        time_steps=t_steps, swap=("p0", "p1"),
                        inner=st.pallas(time_block=k_inner))
    fused = dist.lower_distributed(k_ir, halos, shape, None, be, mesh)
    got = fused(dict(arrays), scal)

    step = dist.lower_distributed(k_ir, halos, shape, None, be1, mesh)
    ref = dict(arrays)
    for _ in range(k_total):
        out = step(ref, scal)
        ref = dict(out, p0=ref["p1"], p1=out["p0"])

    for g in ("p0", "p1"):
        err = float(jnp.abs(got[g] - ref[g]).max())
        assert err < 1e-6, (t_steps, k_inner, g, err)
    print("OK compose", t_steps, "x", k_inner)

# through the engine: fuse window -> (kw / k_inner) skewing groups
p0, p1, vp2, damp, dt = acoustic.make_fields(shape, pml_width=4)
acoustic.inject_source(p1, 0)
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 overlap=False), mesh=mesh)(
    acoustic.acoustic_target)(p0, p1, vp2, damp, dt, 6)
ref0, ref1 = np.asarray(p0.data), np.asarray(p1.data)

q = acoustic.make_fields(shape, pml_width=4)
acoustic.inject_source(q[1], 0)
st.launch(backend=st.distributed(grid_axes=("data", "model", None),
                                 inner=st.pallas(time_block=2)),
          mesh=mesh, fuse_steps=2)(
    lambda *a: st.timeloop(6, swap=("p0", "p1"))(
        acoustic.acoustic_iso_kernel)(*a))(*q[:5])
err = max(float(np.abs(np.asarray(q[0].data) - ref0).max()),
          float(np.abs(np.asarray(q[1].data) - ref1).max()))
assert err < 1e-6, err
print("OK engine-compose")
""")
