"""Tests for the §Perf features: grouped-query decode attention,
kv-cache sharding mode selection, grad-accumulator pinning, the HLO
charge model, the stencil traffic model, and time-skew input validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _qkv(B=2, Sq=3, Sk=16, H=8, K=4, D=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sk, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sk, K, D)), jnp.float32)
    mask = jnp.asarray(rng.random((B, 1, Sq, Sk)) > 0.3)
    return q, k, v, mask


@pytest.mark.parametrize("kv_mode", ["heads", "seq"])
def test_grouped_sdpa_matches_expanded(kv_mode):
    q, k, v, mask = _qkv()
    a = L._sdpa(q, k, v, mask, 0.25, kv_mode=None)
    b = L._sdpa(q, k, v, mask, 0.25, kv_mode=kv_mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_grouped_sdpa_mqa():
    q, k, v, mask = _qkv(K=1)
    a = L._sdpa(q, k, v, mask, 0.25, kv_mode=None)
    b = L._sdpa(q, k, v, mask, 0.25, kv_mode="seq")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_kv_cache_mode_selection():
    import dataclasses
    from repro import configs, sharding
    cfg8 = configs.get("granite-8b")     # kv=8
    cfg16 = configs.get("gemma-7b")      # kv=16
    assert sharding.kv_cache_mode(cfg8) is None   # no mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding.use_activation_mesh(mesh):
        assert sharding.kv_cache_mode(cfg8) is None  # model axis size 1


def test_constrain_like_params_noop_without_mesh():
    from repro import configs, sharding
    cfg = configs.tiny(configs.get("granite-8b"))
    tree = {"layers": {"attn": {"wq": jnp.ones((4, 2, 2))}}}
    out = sharding.constrain_like_params(tree, cfg)
    np.testing.assert_array_equal(np.asarray(out["layers"]["attn"]["wq"]),
                                  np.ones((4, 2, 2)))


def test_charge_model_dus_and_slice():
    """In-place DUS charges the update, dynamic-slice charges the slice."""
    from repro.launch import hlo_analysis as H
    hlo = """
HloModule t

ENTRY %main (p.1: f32[1024,1024], u.1: f32[1,1024], i.1: s32[]) -> f32[1024,1024] {
  %p.1 = f32[1024,1024] parameter(0)
  %u.1 = f32[1,1024] parameter(1)
  %i.1 = s32[] parameter(2)
  %c.1 = s32[] constant(0)
  %ds.1 = f32[1,1024] dynamic-slice(%p.1, %i.1, %c.1), dynamic_slice_sizes={1,1024}
  %a.1 = f32[1,1024] add(%ds.1, %u.1)
  ROOT %dus.1 = f32[1024,1024] dynamic-update-slice(%p.1, %a.1, %i.1, %c.1)
}
"""
    st = H.analyze(hlo, 1)
    # ds: 2×4KB, add: 3×4KB, dus: 2×4KB — NOT 2×4MB buffers
    assert st.hbm_bytes < 100 * 1024, st.hbm_bytes


def test_charge_model_scan_xs_sliced():
    """A fusion param consumed only via dynamic-slice charges slice bytes."""
    from repro.launch import hlo_analysis as H
    hlo = """
HloModule t

%fused (fp0: f32[64,1024], fp1: s32[]) -> f32[1,1024] {
  %fp0 = f32[64,1024] parameter(0)
  %fp1 = s32[] parameter(1)
  %c.2 = s32[] constant(0)
  %ds.2 = f32[1,1024] dynamic-slice(%fp0, %fp1, %c.2), dynamic_slice_sizes={1,1024}
  ROOT %n.1 = f32[1,1024] negate(%ds.2)
}

ENTRY %main (xs.1: f32[64,1024], j.1: s32[]) -> f32[1,1024] {
  %xs.1 = f32[64,1024] parameter(0)
  %j.1 = s32[] parameter(1)
  ROOT %f.1 = f32[1,1024] fusion(%xs.1, %j.1), kind=kLoop, calls=%fused
}
"""
    st = H.analyze(hlo, 1)
    # result 4KB + sliced operand 4KB — not the 256KB xs buffer
    assert st.hbm_bytes <= 3 * 4096 + 64, st.hbm_bytes


def test_stencil_roofline_model():
    from benchmarks import stencil_roofline
    rows = stencil_roofline.run(verbose=False)
    assert all(r["vmem_ok"] for r in rows)
    best = max(rows, key=lambda r: r["roofline_frac"])
    # streaming templates must beat 3D blocking, and reach ≥90% of the
    # 20 B/pt floor at the large block
    assert best["template"] in ("shift", "unroll", "semi")
    assert best["roofline_frac"] >= 0.90
    gmem = [r for r in rows if r["template"] == "gmem"][0]
    assert best["bytes_per_point"] < gmem["bytes_per_point"]


def test_time_skew_validation_errors():
    from repro.core import acoustic, distributed as dist, dsl as st
    k = acoustic.acoustic_iso_kernel
    halos = {g: k.info.halo for g in k.ir.grid_params}
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="swap"):
        dist.lower_distributed(
            k.ir, halos, (32, 32, 32), None,
            st.distributed(grid_axes=("data", None, None), time_steps=2),
            mesh)
    with pytest.raises(ValueError, match="exceeds local extent"):
        dist.lower_distributed(
            k.ir, halos, (4, 32, 32), None,
            st.distributed(grid_axes=("data", None, None), time_steps=3,
                           swap=("p0", "p1")), mesh)


def test_moe_dropless_capacity():
    import dataclasses
    from repro import configs
    from repro.models import api, moe
    cfg = configs.tiny(configs.get("mixtral-8x7b"))
    # force tiny capacity: dropping must occur in capacity mode but not in
    # dropless mode
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)),
                    jnp.float32)
    y_cap, _ = moe.moe_ffn(lp["moe"], x, cfg)
    y_free, _ = moe.moe_ffn(lp["moe"], x, cfg, dropless=True)
    assert not np.allclose(np.asarray(y_cap), np.asarray(y_free))
    # dropless at high capacity factor == capacity mode (nothing dropped)
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    y2, _ = moe.moe_ffn(lp["moe"], x, cfg2)
    y3, _ = moe.moe_ffn(lp["moe"], x, cfg2, dropless=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3),
                               rtol=1e-5, atol=1e-6)
