"""Training-substrate tests: optimizer, microbatching-equivalence, data
determinism, checkpoint-restart bitwise reproducibility, elastic re-shard,
failure injection, gradient compression."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import ShapeSpec
from repro.models import api
from repro.train import checkpoint, data, fault_tolerance, optimizer, train_loop

CFG = configs.tiny(configs.get("granite-8b"))
SHAPE = ShapeSpec("smoke", "train", seq_len=32, global_batch=8)


def _tc(n_mb=1, steps=50):
    return train_loop.TrainConfig(
        opt=optimizer.OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps),
        n_microbatches=n_mb)


def _batch(step=0):
    return {k: jnp.asarray(v)
            for k, v in data.make_batch_fn(CFG, SHAPE, seed=0)(step).items()}


# -- optimizer ----------------------------------------------------------------
def test_schedule_warmup_cosine():
    oc = optimizer.OptConfig(lr=1e-2, warmup_steps=10, total_steps=100,
                             min_lr_ratio=0.1)
    lrs = [float(optimizer.schedule(oc, jnp.int32(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-3) < 1e-9
    assert abs(lrs[2] - 1e-2) < 1e-9
    assert lrs[2] > lrs[3] > lrs[4]
    assert abs(lrs[4] - 1e-3) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = optimizer.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    cn = optimizer.global_norm(clipped)
    assert abs(float(cn) - 1.0) < 1e-5


def test_global_norm_empty_tree():
    # jnp.stack([]) used to raise on an empty pytree
    assert float(optimizer.global_norm({})) == 0.0
    assert float(optimizer.global_norm([])) == 0.0
    clipped, norm = optimizer.clip_by_global_norm({}, 1.0)
    assert clipped == {} and float(norm) == 0.0


def test_bare_array_params_skip_weight_decay():
    # a bare 2-D array passed as the whole params tree (the fwi.py velocity
    # grid) is a physical field, not a matmul weight: no decay
    c = optimizer.OptConfig(lr=0.1, warmup_steps=0, total_steps=10,
                            weight_decay=0.5)
    params = jnp.ones((4, 4))
    grads = jnp.zeros((4, 4))
    state = optimizer.init(params)
    new_p, _, _ = optimizer.apply(c, params, grads, state, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(new_p), np.asarray(params))
    # the same matrix inside a tree IS decayed
    new_t, _, _ = optimizer.apply(c, {"w": params}, {"w": grads},
                                  optimizer.init({"w": params}),
                                  jnp.int32(0))
    assert float(jnp.abs(new_t["w"] - params).max()) > 0


def test_bare_array_adamw_descends():
    # end-to-end bare-array usage: minimize ||p - target||² on one grid
    c = optimizer.OptConfig(lr=0.1, warmup_steps=0, total_steps=50,
                            weight_decay=0.1)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(6, 6)),
                         jnp.float32)
    params = jnp.zeros((6, 6))
    state = optimizer.init(params)

    def loss(p):
        return jnp.sum((p - target) ** 2)

    l0 = float(loss(params))
    for s in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = optimizer.apply(c, params, g, state,
                                           jnp.int32(s))
    assert float(loss(params)) < 0.05 * l0


def test_adamw_decreases_loss():
    state = train_loop.init_state(CFG, jax.random.PRNGKey(0))
    step = jax.jit(train_loop.make_train_step(CFG, _tc()))
    losses = []
    for s in range(30):
        state, m = step(state, _batch(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatching_matches_full_batch():
    """grad accumulation over 4 microbatches == single big batch update."""
    state1 = train_loop.init_state(CFG, jax.random.PRNGKey(0))
    state4 = jax.tree.map(jnp.copy, state1)
    step1 = jax.jit(train_loop.make_train_step(CFG, _tc(1)))
    step4 = jax.jit(train_loop.make_train_step(CFG, _tc(4)))
    b = _batch(0)
    s1, m1 = step1(state1, b)
    s4, m4 = step4(state4, b)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    for a, b2 in zip(jax.tree.leaves(s1["params"]),
                     jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b2, np.float32),
                                   rtol=2e-3, atol=2e-5)


# -- data ---------------------------------------------------------------------
def test_data_deterministic_and_stateless():
    fn = data.make_batch_fn(CFG, SHAPE, seed=3)
    a = fn(7)
    b = fn(7)
    c = fn(8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    # labels are next-token shifted
    fn0 = data.SyntheticLM(data.DataConfig(vocab=CFG.vocab, seq_len=16,
                                           global_batch=2, seed=0, noise=0.0))
    d = fn0.batch(0)
    assert ((5 * d["tokens"][:, 0] + 17) % CFG.vocab
            == d["labels"][:, 0]).all()


# -- checkpointing ------------------------------------------------------------
def test_checkpoint_roundtrip_atomic(tmp_path):
    state = train_loop.init_state(CFG, jax.random.PRNGKey(1))
    d = str(tmp_path / "ck")
    checkpoint.save(d, 10, state)
    checkpoint.save(d, 20, state)
    assert checkpoint.steps(d) == [10, 20]
    restored = checkpoint.restore(d, state, step=10)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    checkpoint.prune(d, keep=1)
    assert checkpoint.steps(d) == [20]


def test_checkpoint_restart_bitwise(tmp_path):
    """kill at step 7, restart → same final params as uninterrupted run."""
    d = str(tmp_path / "ck")
    step_jit = jax.jit(train_loop.make_train_step(CFG, _tc()))

    def init_fn():
        return train_loop.init_state(CFG, jax.random.PRNGKey(0))

    def one(state, step):
        state, _ = step_jit(state, _batch(step))
        return state

    inj = fault_tolerance.FailureInjector([7])
    final = fault_tolerance.run_with_restarts(
        init_fn=init_fn, step_fn=one, n_steps=12, ckpt_dir=d,
        ckpt_every=5, injector=inj)

    ref = init_fn()
    for s in range(12):
        ref = one(ref, s)
    for a, b in zip(jax.tree.leaves(final["params"]),
                    jax.tree.leaves(ref["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_restore(tmp_path):
    """checkpoint written unsharded restores onto a different mesh layout."""
    import subprocess, sys, textwrap
    d = str(tmp_path / "ck")
    state = train_loop.init_state(CFG, jax.random.PRNGKey(2))
    checkpoint.save(d, 1, state)
    code = textwrap.dedent(f"""
        import jax, numpy as np
        from repro import configs, sharding
        from repro.train import checkpoint, train_loop
        cfg = configs.tiny(configs.get("granite-8b"))
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        specs = train_loop.state_specs(cfg)
        shard = train_loop.state_shardings(cfg, mesh)
        st = checkpoint.restore({d!r}, specs, shardings=shard)
        leaf = st["params"]["final_norm"]["scale"]
        assert len(leaf.sharding.device_set) >= 1
        total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(st))
        print("restored", total)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr
    assert "restored" in r.stdout


def test_watchdog_flags_straggler():
    wd = fault_tolerance.Watchdog(threshold=3.0)
    for s in range(10):
        wd.observe(s, 0.1)
    ev = wd.observe(10, 1.0)
    assert ev is not None and ev.step == 10
    assert len(wd.events) == 1


# -- gradient compression -----------------------------------------------------
def test_int8_compression_roundtrip_and_neutrality():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                          jnp.float32)}
    c = optimizer.compress_int8(g)
    back = optimizer.decompress_int8(c)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    assert err <= float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-6

    # convergence-neutral on the smoke model: compressed-grad training
    # reaches a loss within 5% of exact-grad training
    def run(compress):
        tc = _tc()
        state = train_loop.init_state(CFG, jax.random.PRNGKey(0))
        base = train_loop.make_train_step(CFG, tc)

        def step(state, batch):
            return base(state, batch)

        if compress:
            grad_fn = jax.value_and_grad(
                lambda p, b: api.loss_fn(CFG, p, b)[0])

            def step(state, batch):  # noqa: F811
                loss, g = grad_fn(state["params"], batch)
                g = optimizer.decompress_int8(optimizer.compress_int8(g))
                new_p, new_o, m = optimizer.apply(
                    tc.opt, state["params"], g, state["opt"], state["step"])
                return ({"params": new_p, "opt": new_o,
                         "step": state["step"] + 1},
                        {"loss": loss, **m})

        step = jax.jit(step)
        for s in range(20):
            state, m = step(state, _batch(s))
        return float(m["loss"])

    exact = run(False)
    comp = run(True)
    assert abs(comp - exact) / abs(exact) < 0.05, (exact, comp)
