"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / decode step on CPU, asserting output shapes + finite values.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.models import api

ARCHS = list(configs.ARCH_NAMES)
B, S = 2, 32


def _tiny(name):
    return configs.tiny(configs.get(name))


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "audio":
        batch["frame_embeds"] = rng.standard_normal(
            (B, 16, cfg.d_model)).astype(np.float32)
    elif cfg.family == "vlm":
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = _tiny(name)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hid, aux = api.forward_hidden(cfg, params, batch)
    S_tok = batch["tokens"].shape[1]
    assert hid.shape == (B, S_tok, cfg.d_model), hid.shape
    assert np.isfinite(np.asarray(hid, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_loss_and_grad_step(name):
    cfg = _tiny(name)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    @jax.jit
    def loss_fn(p):
        loss, m = api.loss_fn(cfg, p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    # init CE should be near ln(vocab) — catches logit-scale bugs
    assert float(loss) < 2.0 * np.log(cfg.vocab) + 1.0
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    """Teacher-forced decode over a cache must reproduce full-sequence
    forward logits (the serving path's correctness invariant)."""
    cfg = _tiny(name)
    cfg = dataclasses.replace(cfg, remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (B, 8)).astype(np.int32)

    if cfg.family == "audio":
        frames = rng.standard_normal((B, 16, cfg.d_model)).astype(np.float32)
        enc = api.module_for(cfg).encode(params, jnp.asarray(frames), cfg)
        hid = api.module_for(cfg).decode_train(params, enc, toks, cfg)
        from repro.models import layers as L
        full_logits = L.unembed(params["embed"], hid, cfg)
        from repro.models import encdec
        cache = encdec.build_cache(params, enc, cfg, B, cache_len=16)
    else:
        batch = {"tokens": toks, "labels": toks}
        hid, _ = api.forward_hidden(cfg, params, batch)
        from repro.models import layers as L
        full_logits = L.unembed(params["embed"], hid, cfg)
        cache_len = api.decode_cache_len(cfg, 16)
        cache = api.init_cache(cfg, B, cache_len)
        if cfg.family == "vlm":
            pytest.skip("vlm decode exercises the token path only (prefix "
                        "is a stub); covered by transformer archs")

    step = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    got = []
    for i in range(toks.shape[1]):
        logits, cache = step(params, cache, toks[:, i:i + 1])
        got.append(np.asarray(logits[:, 0], np.float32))
    got = np.stack(got, axis=1)
    want = np.asarray(full_logits, np.float32)
    np.testing.assert_allclose(got, want, rtol=0.15, atol=0.15)
    # argmax agreement is the serving-level invariant
    agree = (got.argmax(-1) == want.argmax(-1)).mean()
    assert agree > 0.95, agree


@pytest.mark.parametrize("name", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_complete(name, shape_name):
    """Every non-skipped (arch × shape) cell has well-formed input specs."""
    cfg = configs.get(name)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        assert "full-attention" in reason
        return
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    leaves = jax.tree.leaves(specs)
    assert all(hasattr(l, "shape") and hasattr(l, "dtype") for l in leaves)
    if shape.kind == "decode":
        assert "cache" in specs
        assert specs["tokens"].shape == (shape.global_batch, 1)
    elif cfg.family not in ("audio", "vlm"):
        assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)


def test_assigned_cell_count():
    """40 assigned cells; exactly the 6 documented long_500k skips."""
    n_run = n_skip = 0
    for name in ARCHS:
        cfg = configs.get(name)
        for shape in SHAPES.values():
            ok, _ = applicable(cfg, shape)
            n_run += ok
            n_skip += not ok
    assert n_run + n_skip == 40
    assert n_skip == 6


def test_param_counts_match_published():
    expected = {
        "mixtral-8x7b": 46.7e9, "mixtral-8x22b": 141e9, "granite-8b": 8.2e9,
        "gemma-7b": 8.5e9, "phi3-mini-3.8b": 3.8e9, "nemotron-4-15b": 15.6e9,
        "recurrentgemma-9b": 9.4e9, "xlstm-1.3b": 1.2e9,
        "pixtral-12b": 12.3e9, "whisper-small": 0.24e9,
    }
    for name, want in expected.items():
        got = api.param_count(configs.get(name))
        assert abs(got - want) / want < 0.12, (name, got, want)


@pytest.mark.parametrize("name", ["mixtral-8x7b", "recurrentgemma-9b",
                                  "xlstm-1.3b"])
def test_long_context_decode_cache_is_bounded(name):
    """long_500k runs only because decode state is O(window)/O(1)."""
    cfg = configs.get(name)
    cl = api.decode_cache_len(cfg, SHAPES["long_500k"].seq_len)
    assert cl <= 4096, cl
