"""Batched (vmapped scenario) timeloop: B-scenario runs must equal B
independent serial runs — across backends/templates × temporal depths ×
2D/3D — including per-scenario scalar parameters, hook cadence, and the
masked serving windows (spatial sub-domain freeze + per-scenario step
budgets)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dsl as st, suite
from repro.core.timeloop import TimeloopEngine

B = 3
STEPS = 6


def _inits(k, shape, seed=0):
    rng = np.random.default_rng(seed)
    return {g: rng.standard_normal((B,) + shape).astype(np.float32)
            for g in k.ir.grid_params}


def _serial(k, shape, inits, backend, time_block=1, fuse=None, steps=STEPS,
            scalars=()):
    outs = []
    for b in range(B):
        gs = {g: st.grid(st.f32, shape, k.info.order)
              for g in k.ir.grid_params}
        for g in gs:
            gs[g].interior = inits[g][b]
        args = [gs[g] for g in k.ir.grid_params] + [s[b] for s in scalars]

        def run():
            st.timeloop(steps, swap=suite.swap_pair(k.name)
                        if not scalars else ("v", "u"),
                        fuse_steps=fuse)(k)(*args)
        st.launch(backend=backend, time_block=time_block)(run)()
        outs.append({g: np.asarray(gs[g].interior) for g in gs})
    return outs


def _batched(k, shape, inits, backend, time_block=1, fuse=None, steps=STEPS,
             scalars=()):
    gs = {g: st.grid(st.f32, shape, k.info.order, batch=B)
          for g in k.ir.grid_params}
    for g in gs:
        gs[g].interior = inits[g]
    args = [gs[g] for g in k.ir.grid_params] + [jnp.asarray(s) for s in scalars]

    def run():
        st.timeloop(steps, swap=suite.swap_pair(k.name)
                    if not scalars else ("v", "u"),
                    fuse_steps=fuse, batch=B)(k)(*args)
    st.launch(backend=backend, time_block=time_block)(run)()
    return {g: np.asarray(gs[g].interior) for g in gs}


def _assert_equal(bat, ser, label):
    for g in bat:
        for b in range(B):
            np.testing.assert_allclose(
                bat[g][b], ser[b][g], rtol=1e-5, atol=1e-6,
                err_msg=f"{label} {g} scenario={b}")


# ---- equivalence: templates × temporal depth × dimensionality --------------
@pytest.mark.parametrize("time_block", (1, 4))
@pytest.mark.parametrize("template", ("gmem", "smem", "shift"))
def test_batched_matches_serial_pallas_2d(template, time_block):
    k = suite.get_kernel("star2d1r")
    shape = (12, 18)
    inits = _inits(k, shape)
    be = st.pallas(template=template)
    ser = _serial(k, shape, inits, be, time_block)
    bat = _batched(k, shape, inits, be, time_block)
    _assert_equal(bat, ser, f"{template}/k={time_block}")


@pytest.mark.parametrize("time_block", (1, 4))
def test_batched_matches_serial_pallas_3d(time_block):
    k = suite.get_kernel("star3d1r")
    shape = (6, 8, 10)
    inits = _inits(k, shape)
    be = st.pallas(template="gmem")
    ser = _serial(k, shape, inits, be, time_block, steps=4)
    bat = _batched(k, shape, inits, be, time_block, steps=4)
    _assert_equal(bat, ser, f"3d/k={time_block}")


@pytest.mark.parametrize("shape,name", [((12, 18), "star2d1r"),
                                        ((6, 8, 10), "star3d1r")])
def test_batched_matches_serial_xla(shape, name):
    k = suite.get_kernel(name)
    inits = _inits(k, shape)
    ser = _serial(k, shape, inits, st.xla(), fuse=2)
    bat = _batched(k, shape, inits, st.xla(), fuse=2)
    _assert_equal(bat, ser, f"xla/{name}")


# ---- per-scenario scalar parameters ----------------------------------------
@st.kernel
def _damped(u: st.grid, v: st.grid, a: st.f32):
    v.at(0, 0).set(a * u.at(0, 0)
                   + 0.1 * (u.at(-1, 0) + u.at(1, 0)
                            + u.at(0, -1) + u.at(0, 1)))


def test_batched_per_scenario_scalars():
    """(B,) scalar args give each scenario its own parameter value."""
    shape = (10, 14)
    inits = _inits(_damped, shape)
    a = np.array([0.3, 0.5, 0.7], np.float32)
    ser = _serial(_damped, shape, inits, st.xla(), scalars=(a,))
    bat = _batched(_damped, shape, inits, st.xla(), scalars=(a,))
    _assert_equal(bat, ser, "per-scenario scalar")
    # distinct parameters must produce distinct fields
    assert not np.allclose(bat["v"][0], bat["v"][1])


def test_batched_broadcast_scalar():
    """A python float is shared across scenarios."""
    shape = (10, 14)
    inits = _inits(_damped, shape)
    a = np.array([0.5, 0.5, 0.5], np.float32)
    ser = _serial(_damped, shape, inits, st.xla(), scalars=(a,))

    gs = {g: st.grid(st.f32, shape, 1, batch=B) for g in ("u", "v")}
    for g in gs:
        gs[g].interior = inits[g]
    st.launch(backend=st.xla())(lambda: st.timeloop(
        STEPS, swap=("v", "u"), batch=B)(_damped)(gs["u"], gs["v"], 0.5))()
    bat = {g: np.asarray(gs[g].interior) for g in gs}
    _assert_equal(bat, ser, "broadcast scalar")


# ---- hook cadence ----------------------------------------------------------
def test_batched_between_hook_cadence():
    """The between hook fires at exactly the window boundaries and sees
    the batched grids; injecting per-scenario sources stays equivalent to
    serial runs doing the same."""
    k = suite.get_kernel("star2d1r")
    shape = (10, 12)
    inits = _inits(k, shape)
    hits = []

    def mk_between(amps):
        def between(t, grids):
            hits.append(t)
            u = grids["u"]
            inj = np.zeros(u.interior.shape, np.float32)
            inj[..., 4, 5] = amps if np.ndim(amps) else float(amps)
            u.interior = u.interior + inj
        return between

    amps = np.array([1.0, 2.0, 3.0], np.float32)
    gs = {g: st.grid(st.f32, shape, k.info.order, batch=B)
          for g in k.ir.grid_params}
    for g in gs:
        gs[g].interior = inits[g]
    st.launch(backend=st.xla())(lambda: st.timeloop(
        STEPS, swap=("v", "u"), fuse_steps=2, batch=B,
        between=mk_between(amps))(k)(gs["u"], gs["v"]))()
    assert hits == [2, 4]      # every fuse window boundary except the last
    bat = {g: np.asarray(gs[g].interior) for g in gs}

    ser = []
    for b in range(B):
        hits.clear()
        g1 = {g: st.grid(st.f32, shape, k.info.order)
              for g in k.ir.grid_params}
        for g in g1:
            g1[g].interior = inits[g][b]
        st.launch(backend=st.xla())(lambda: st.timeloop(
            STEPS, swap=("v", "u"), fuse_steps=2,
            between=mk_between(amps[b]))(k)(g1["u"], g1["v"]))()
        assert hits == [2, 4]
        ser.append({g: np.asarray(g1[g].interior) for g in g1})
    _assert_equal(bat, ser, "between hook")


# ---- masked serving windows ------------------------------------------------
def _engine(k, shape, backend=None, batch=B):
    halos = {g: (k.info.order,) * k.info.ndim for g in k.ir.grid_params}
    return TimeloopEngine(k.ir, halos, shape, backend or st.xla(),
                          swap=suite.swap_pair(k.name), batch=batch)


def test_masked_step_limits_and_subdomain():
    """One wave: full-domain scenario, early-stopping scenario, and an
    embedded smaller sub-domain — each equals its serial reference."""
    k = suite.get_kernel("star2d1r")
    shape, sub, order = (12, 18), (8, 10), k.info.order
    inits = _inits(k, shape)
    eng = _engine(k, shape)
    arrays = {}
    for g in k.ir.grid_params:
        full = np.zeros((B,) + tuple(s + 2 * order for s in shape),
                        np.float32)
        full[:2, order:order + shape[0], order:order + shape[1]] = \
            inits[g][:2]
        # scenario 2: zero outside the sub-domain = the small grid's halos
        full[2, order:order + sub[0], order:order + sub[1]] = \
            inits[g][2][:sub[0], :sub[1]]
        arrays[g] = jnp.asarray(full)
    mask = np.zeros((B,) + shape, bool)
    mask[0] = mask[1] = True
    mask[2, :sub[0], :sub[1]] = True
    limits = np.array([STEPS, 2, STEPS], np.int32)
    out = eng.run(arrays, {}, STEPS, 3, domain_mask=jnp.asarray(mask),
                  step_limits=jnp.asarray(limits))

    def ref(b, steps, shp):
        gs = {g: st.grid(st.f32, shp, order) for g in k.ir.grid_params}
        for g in gs:
            gs[g].interior = inits[g][b][tuple(slice(0, e) for e in shp)]
        if steps:
            st.launch(backend=st.xla())(lambda: st.timeloop(
                steps, swap=("v", "u"))(k)(gs["u"], gs["v"]))()
        return {g: np.asarray(gs[g].interior) for g in gs}

    for b, steps, shp in [(0, STEPS, shape), (1, 2, shape), (2, STEPS, sub)]:
        want = ref(b, steps, shp)
        for g in k.ir.grid_params:
            idx = (b,) + tuple(slice(order, order + e) for e in shp)
            np.testing.assert_allclose(
                np.asarray(out[g][idx]), want[g], rtol=1e-5, atol=1e-6,
                err_msg=f"masked scenario={b} {g}")


def test_masked_frozen_cells_keep_values():
    """Cells outside every mask stay bit-identical to their inputs."""
    k = suite.get_kernel("star2d1r")
    shape, order = (8, 8), k.info.order
    inits = _inits(k, shape)
    eng = _engine(k, shape)
    arrays = {g: jnp.asarray(np.pad(inits[g],
                                    [(0, 0), (order, order), (order, order)]))
              for g in k.ir.grid_params}
    mask = np.zeros((B,) + shape, bool)
    mask[:, :4, :4] = True
    out = eng.run(arrays, {}, 4, domain_mask=jnp.asarray(mask))
    for g in k.ir.grid_params:
        got = np.asarray(out[g][:, order:order + 8, order:order + 8])
        np.testing.assert_array_equal(got[:, 6:, 6:], inits[g][:, 6:, 6:])


# ---- validation ------------------------------------------------------------
def test_grid_batch_views():
    g = st.grid(st.f32, (4, 6), order=2, batch=5).randomize(1)
    assert g.data.shape == (5, 8, 10)
    assert g.interior.shape == (5, 4, 6)
    assert "batch=5" in repr(g)
    c = g.copy()
    assert c.batch == 5 and c.data.shape == g.data.shape
    # distinct scenarios get distinct random fields
    assert not np.allclose(np.asarray(g.interior[0]),
                           np.asarray(g.interior[1]))


def test_batch_mismatch_raises():
    k = suite.get_kernel("star2d1r")
    u = st.grid(st.f32, (8, 8), 1, batch=2)
    v = st.grid(st.f32, (8, 8), 1, batch=3)
    with pytest.raises(ValueError, match="batch"):
        st.timeloop(2, swap=("v", "u"), batch=2)(k)(u, v)
    v2 = st.grid(st.f32, (8, 8), 1)
    with pytest.raises(ValueError, match="batch"):
        st.timeloop(2, swap=("v", "u"), batch=2)(k)(u, v2)


def test_map_rejects_batched_grids():
    k = suite.get_kernel("star2d1r")
    u = st.grid(st.f32, (8, 8), 1, batch=2)
    v = st.grid(st.f32, (8, 8), 1, batch=2)
    with pytest.raises(ValueError, match="batched"):
        st.map(e=u.shape)(k)(u, v)


def test_masked_requires_batched_xla():
    k = suite.get_kernel("star2d1r")
    eng = _engine(k, (8, 8), batch=0)
    arrays = {g: jnp.zeros((10, 10)) for g in k.ir.grid_params}
    with pytest.raises(ValueError, match="batched xla"):
        eng.run(arrays, {}, 2, step_limits=jnp.array([1]))
    peng = _engine(k, (8, 8), backend=st.pallas(template="gmem"))
    parrs = {g: jnp.zeros((B, 10, 10)) for g in k.ir.grid_params}
    with pytest.raises(ValueError, match="batched xla"):
        peng.run(parrs, {}, 2,
                 domain_mask=jnp.ones((B, 8, 8), bool))


@pytest.mark.parametrize("time_steps,fuse", [(1, 2), (2, 6)])
def test_distributed_batched_matches_serial(time_steps, fuse):
    """Batched grids thread through the fused sharded timeloop: the batch
    axis rides unsharded ahead of the mesh-decomposed grid axes (a vmap
    inside the single shard_mapped program), so B scenarios on a mesh must
    equal B independent runs.  Single-device mesh — the multi-device
    variant lives in test_distributed.py's subprocess harness."""
    import jax
    k = suite.get_kernel("star2d1r")
    shape = (12, 18)
    inits = _inits(k, shape)
    mesh = jax.make_mesh((1,), ("data",))
    be = st.distributed(grid_axes=("data", None), time_steps=time_steps)

    ser = _serial(k, shape, inits, st.xla(), fuse=fuse)

    gs = {g: st.grid(st.f32, shape, k.info.order, batch=B)
          for g in k.ir.grid_params}
    for g in gs:
        gs[g].interior = inits[g]

    def run():
        st.timeloop(STEPS, swap=suite.swap_pair(k.name),
                    fuse_steps=fuse, batch=B)(k)(*gs.values())
    st.launch(backend=be, mesh=mesh)(run)()
    bat = {g: np.asarray(gs[g].interior) for g in gs}
    _assert_equal(bat, ser, f"dist/ts={time_steps}/fuse={fuse}")
