"""Differentiable timeloop tests: gradient-vs-central-finite-difference
across the template suite (xla + pallas interpret, time_block 1 and 4),
per-scenario batched gradients, masked-window adjoint freezes, the O(√T)
checkpoint bound, primal equivalence with the forward engine, and the
donation-under-AD regression (``_donate_ok``)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import adjoint, dsl as st, suite
from repro.core import timeloop as tl

TEMPLATES = ("gmem", "smem", "f4", "shift", "unroll", "semi")
# shape indivisible by the default block axes (same as test_timeloop's
# temporal-blocking geometry) so block-overhang masks are in play
SHAPE = (13, 21)
NAME = "star2d2r"


def _engine(backend, dtype=jnp.float64, shape=SHAPE, name=NAME, batch=0):
    k = suite.get_kernel(name)
    grids = {g: st.grid(dtype=dtype, shape=shape, order=k.info.order,
                        batch=batch or None).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    arrays = {n: jnp.asarray(g.data, dtype) for n, g in grids.items()}
    eng = tl.TimeloopEngine(k.ir, {n: g.halo for n, g in grids.items()},
                            shape, backend, swap=suite.swap_pair(name),
                            batch=batch, differentiable=True)
    return eng, arrays


def _check_grad_vs_fd(fn, arrays, scal, tag, n_probes=2, eps=1e-6,
                      rtol=1e-3):
    """Central-FD check of d(sum of squares of outputs)/d(arrays) at a few
    randomly chosen input cells per grid (f64)."""
    def loss(arrs):
        out = fn(arrs, scal)
        return sum(jnp.sum(o ** 2) for o in out.values())

    grad = jax.grad(loss)(arrays)
    rng = np.random.default_rng(7)
    for g, a in arrays.items():
        a = np.asarray(a)
        for _ in range(n_probes):
            idx = tuple(int(rng.integers(0, s)) for s in a.shape)
            ap, am = a.copy(), a.copy()
            ap[idx] += eps
            am[idx] -= eps
            fd = (float(loss({**arrays, g: jnp.asarray(ap)}))
                  - float(loss({**arrays, g: jnp.asarray(am)}))) / (2 * eps)
            ad = float(np.asarray(grad[g])[idx])
            err = abs(ad - fd) / max(abs(fd), abs(ad), 1e-8)
            assert err < rtol, (f"{tag}/{g}{idx}: AD {ad} vs FD {fd} "
                                f"(rel err {err:.2e})")


# ---- gradient == finite differences: xla ----------------------------------
@pytest.mark.parametrize("fuse", (1, 4, None))
def test_grad_vs_fd_xla(fuse):
    with enable_x64():
        eng, arrays = _engine(st.xla())
        fn = adjoint.differentiable_run(eng, 5, fuse_steps=fuse)
        _check_grad_vs_fd(fn, arrays, {}, f"xla/fuse={fuse}")


# ---- gradient == finite differences: every pallas template × time_block ---
@pytest.mark.parametrize("template", TEMPLATES)
@pytest.mark.parametrize("time_block", (1, 4))
def test_grad_vs_fd_pallas_templates(template, time_block):
    with enable_x64():
        backend = st.pallas(template=template, interpret=True,
                            time_block=time_block)
        eng, arrays = _engine(backend)
        # fuse 5 at time_block=4: one 4-deep blocked group + a single-step
        # remainder inside the window, both on the adjoint's replay path
        fn = adjoint.differentiable_run(
            eng, 5, fuse_steps=5 if time_block == 4 else None)
        _check_grad_vs_fd(fn, arrays, {},
                          f"pallas/{template}/tb={time_block}", n_probes=1)


# ---- scalar + coefficient-grid gradients (the FWI surface) ----------------
def test_grad_flows_to_scalars_and_coefficient_grid():
    with enable_x64():
        @st.kernel
        def heat(u: st.grid, v: st.grid, c: st.grid, a: st.f32):
            v.at(0, 0).set(u.at(0, 0) + a * c.at(0, 0) * (
                u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)
                - 4.0 * u.at(0, 0)))

        shape = (8, 9)
        grids = {g: st.grid(dtype=jnp.float64, shape=shape,
                            order=1).randomize(i)
                 for i, g in enumerate(("u", "v", "c"))}
        eng = tl.TimeloopEngine(heat.ir,
                                {n: g.halo for n, g in grids.items()},
                                shape, st.xla(), swap=("v", "u"),
                                differentiable=True)
        fn = adjoint.differentiable_run(eng, 6)
        arrays = {n: jnp.asarray(g.data) for n, g in grids.items()}
        scal = {"a": jnp.float64(0.1)}

        def loss(arrs, s):
            return jnp.sum(fn(arrs, s)["v"] ** 2)

        g_arr, g_scal = jax.grad(loss, argnums=(0, 1))(arrays, scal)
        # coefficient grid (velocity-model analogue) gets a real gradient
        assert float(jnp.linalg.norm(g_arr["c"])) > 0
        # scalar gradient matches central FD
        eps = 1e-6
        fd = (float(loss(arrays, {"a": jnp.float64(0.1 + eps)}))
              - float(loss(arrays, {"a": jnp.float64(0.1 - eps)}))) \
            / (2 * eps)
        ad = float(g_scal["a"])
        assert abs(ad - fd) / max(abs(fd), 1e-8) < 1e-3, (ad, fd)


# ---- batched scenarios differentiate per-scenario -------------------------
def test_batched_grads_are_per_scenario():
    with enable_x64():
        B = 3
        eng, arrays = _engine(st.xla(), shape=(8, 10), batch=B)
        fn = adjoint.differentiable_run(eng, 4)

        def loss(arrs):
            return jnp.sum(fn(arrs, {})["v"][1] ** 2)  # scenario 1 only

        scal = {}
        g = jax.grad(loss)(arrays)
        norms = [float(jnp.linalg.norm(g["u"][i])) for i in range(B)]
        assert norms[1] > 0
        assert norms[0] == 0 and norms[2] == 0
        _check_grad_vs_fd(fn, arrays, scal, "batched", n_probes=2)


# ---- masked windows: adjoint freezes masked cells and exhausted steps -----
def test_masked_window_grads_freeze_masked_cells():
    with enable_x64():
        B = 2
        shape = (8, 10)
        eng, arrays = _engine(st.xla(), shape=shape, batch=B)
        mask = np.ones((B,) + shape, bool)
        mask[1, :, 5:] = False                 # scenario 1: right half frozen
        limits = np.array([4, 2], np.int32)    # scenario 1 stops at step 2
        fn = adjoint.differentiable_run(eng, 4, domain_mask=jnp.asarray(mask),
                                        step_limits=jnp.asarray(limits))
        _check_grad_vs_fd(fn, arrays, {}, "masked", n_probes=2)

        # a frozen interior cell's value passes straight through: its
        # cotangent is exactly the output cotangent (identity), and it gets
        # no contribution from neighbours (taps never propagate INTO the
        # frozen region's interior beyond the halo depth)
        def loss(arrs):
            out = fn(arrs, {})
            return jnp.sum(out["u"][1] ** 2) + jnp.sum(out["v"][1] ** 2)

        g = jax.grad(loss)(arrays)
        out = fn(arrays, {})
        o = eng.halos["u"][0]
        # deep inside the frozen half (beyond tap reach of active cells)
        frozen = (1, o + 4, o + 8)
        for gr in ("u", "v"):
            np.testing.assert_allclose(
                float(np.asarray(g[gr])[frozen]),
                2.0 * float(np.asarray(out[gr])[frozen]), rtol=1e-12)


# ---- O(√T) checkpoint bound ----------------------------------------------
@pytest.mark.parametrize("steps", (7, 16, 36, 100))
def test_checkpoint_count_is_sqrt_bounded(steps):
    eng, arrays = _engine(st.xla(), dtype=jnp.float32, shape=(6, 8))
    bound = adjoint.ceil_sqrt(steps) + 1
    # default schedule and a forced fine window cadence both stay √T
    for fuse in (None, 1):
        fn = adjoint.differentiable_run(eng, steps, fuse_steps=fuse)
        assert fn.schedule["checkpoints"] <= bound, fn.schedule
        adjoint.reset_stats()
        jax.grad(lambda a: jnp.sum(fn(a, {})["v"] ** 2))(arrays)
        assert adjoint.CHECKPOINT_STATS["checkpoints"] <= bound
        # backward touched every window exactly once
        assert (adjoint.CHECKPOINT_STATS["vjp_windows"]
                == len(fn.schedule["windows"]))


def test_ceil_sqrt_and_schedule_helpers():
    for n in (0, 1, 2, 3, 4, 8, 9, 15, 16, 17, 100):
        assert adjoint.ceil_sqrt(n) == int(math.ceil(math.sqrt(n)))
    sizes, starts = adjoint.window_schedule(10, 4)
    assert sizes == (4, 4, 2) and starts == (0, 4, 8)
    # stride thins T windows back to ~√T checkpoints
    assert adjoint.checkpoint_stride(100, 100) == 10
    assert adjoint.checkpoint_stride(10, 100) == 1


# ---- primal equivalence with the forward engine ---------------------------
@pytest.mark.parametrize("backend", (st.xla(),
                                     st.pallas(template="gmem",
                                               interpret=True)))
def test_primal_matches_engine_run(backend):
    eng, arrays = _engine(backend, dtype=jnp.float32, shape=(9, 11))
    fn = adjoint.differentiable_run(eng, 5, fuse_steps=2)
    want = eng.run(dict(arrays), {}, 5, fuse_steps=2)
    got = fn(arrays, {})
    for g in arrays:
        np.testing.assert_array_equal(np.asarray(got[g]),
                                      np.asarray(want[g]), err_msg=g)


def test_between_hook_is_differentiated():
    with enable_x64():
        eng, arrays = _engine(st.xla(), shape=(6, 8))

        def between(t, arrs):
            out = dict(arrs)
            out["u"] = out["u"] * 1.01       # pure, traceable
            return out

        fn = adjoint.differentiable_run(eng, 5, fuse_steps=1,
                                        between=between)
        _check_grad_vs_fd(fn, arrays, {}, "between", n_probes=1)


# ---- guard rails ----------------------------------------------------------
def test_requires_differentiable_engine():
    eng, _ = _engine(st.xla(), dtype=jnp.float32, shape=(6, 8))
    eng.differentiable = False
    with pytest.raises(ValueError, match="differentiable=True"):
        adjoint.differentiable_run(eng, 4)


def test_masked_requires_batched_xla():
    eng, _ = _engine(st.xla(), dtype=jnp.float32, shape=(6, 8))
    with pytest.raises(ValueError, match="batched xla"):
        adjoint.differentiable_run(eng, 4, domain_mask=np.ones((6, 8), bool))


# ---- DSL entry point ------------------------------------------------------
def test_dsl_differentiable_timeloop_jits_and_matches_timeloop():
    k = suite.get_kernel("star2d1r")
    grids = {g: st.grid(dtype=st.f32, shape=(10, 12), order=1).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    fn = st.differentiable_timeloop(k, grids["u"], grids["v"], steps=6,
                                    swap=("v", "u"))
    ref_grids = {n: g.copy() for n, g in grids.items()}
    st.launch(backend=st.xla())(
        lambda u, v: st.timeloop(6, swap=("v", "u"))(k)(u, v))(
        ref_grids["u"], ref_grids["v"])
    out = fn()
    for g in grids:
        np.testing.assert_allclose(np.asarray(out[g]),
                                   np.asarray(ref_grids[g].data), atol=1e-6)
    # grad is jittable end-to-end
    gfn = jax.jit(jax.grad(lambda a: jnp.sum(fn(a, {})["v"] ** 2)))
    g = gfn(fn.arrays)
    assert all(bool(jnp.isfinite(v).all()) for v in g.values())


def test_dsl_distributed_backend_differentiates():
    """The distributed backend is no longer forward-only: on a (1-device)
    mesh the DSL entry builds a shard_mapped adjoint whose primal and
    interior gradients match the single-device xla path.  Interiors only:
    the distributed carry convention keeps grid-halo cells fixed at zero
    (not differentiable inputs), while the full-buffer xla window also
    cotangents the halo ring.  (Real multi-device coverage lives in
    tests/test_distributed_adjoint.py.)"""
    k = suite.get_kernel("star2d1r")
    mesh = jax.make_mesh((1,), ("data",))
    grids = {g: st.grid(dtype=st.f32, shape=(8, 8), order=1).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    fn = st.differentiable_timeloop(
        k, grids["u"], grids["v"], steps=4, swap=("v", "u"),
        backend=st.distributed(grid_axes=("data", None)), mesh=mesh)

    ref_grids = {n: g.copy() for n, g in grids.items()}
    fn_ref = st.differentiable_timeloop(
        k, ref_grids["u"], ref_grids["v"], steps=4, swap=("v", "u"))

    ix = (slice(1, -1), slice(1, -1))

    def loss(f, a):
        return jnp.sum(f(a, {})["v"][ix] ** 2)

    out = fn(fn.arrays)
    want = fn_ref(fn_ref.arrays)
    for g in out:
        np.testing.assert_array_equal(np.asarray(out[g][ix]),
                                      np.asarray(want[g][ix]), err_msg=g)
    g_dist = jax.grad(lambda a: loss(fn, a))(fn.arrays)
    g_xla = jax.grad(lambda a: loss(fn_ref, a))(fn_ref.arrays)
    for g in g_dist:
        np.testing.assert_allclose(np.asarray(g_dist[g][ix]),
                                   np.asarray(g_xla[g][ix]),
                                   rtol=1e-5, atol=1e-6, err_msg=g)


def test_dsl_distributed_backend_requires_mesh():
    k = suite.get_kernel("star2d1r")
    grids = {g: st.grid(dtype=st.f32, shape=(8, 8), order=1).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}
    fn = st.differentiable_timeloop(
        k, grids["u"], grids["v"], steps=4, swap=("v", "u"),
        backend=st.distributed(grid_axes=("data", None)))
    with pytest.raises(ValueError, match="mesh"):
        fn(fn.arrays)


# ---- donation gating under differentiation (regression) -------------------
def test_donate_ok_disabled_when_differentiable(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert tl._donate_ok() is True
    assert tl._donate_ok(differentiable=True) is False


def test_donate_ok_disabled_under_trace(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    seen = {}

    def probe(x):
        seen["donate"] = tl._donate_ok()
        return x

    jax.make_jaxpr(probe)(jnp.zeros(1))
    assert seen["donate"] is False


def test_differentiable_engine_windows_do_not_donate(monkeypatch):
    # on a donating backend, a differentiable engine must still compile
    # its windows without donate_argnums — otherwise fwd-pass residual
    # buffers would be invalidated
    captured = {}
    real_jit = jax.jit

    def spy_jit(*a, **kw):
        captured["donate"] = kw.get("donate_argnums", ())
        return real_jit(*a, **kw)

    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    monkeypatch.setattr(jax, "jit", spy_jit)
    eng, _ = _engine(st.xla(), dtype=jnp.float32, shape=(6, 8))
    eng._window(2)
    assert captured["donate"] == ()
