"""Fused time-loop engine tests: equivalence with per-step execution on
the accuracy suite (xla + pallas interpret), the one-pad-per-window layout
invariant, window-boundary hooks, and the fuse_steps autotuner knobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, dsl as st, suite
from repro.kernels.stencil import codegen, ops

STEPS = 5


def _mk_grids(name, seed=0):
    k = suite.get_kernel(name)
    shape = (16, 24) if k.info.ndim == 2 else (8, 10, 16)
    return {g: st.grid(dtype=st.f32, shape=shape,
                       order=k.info.order).randomize(seed + i)
            for i, g in enumerate(k.ir.grid_params)}


def _per_step_reference(name, steps=STEPS):
    """Per-step st.map loop with the name-rotation (data-swap) convention."""
    k = suite.get_kernel(name)
    grids = _mk_grids(name)

    def tgt(u, v):
        for _ in range(steps):
            st.map(e=u.shape)(k)(u, v)
            (u.data, v.data) = (v.data, u.data)

    st.launch(backend=st.xla())(tgt)(grids["u"], grids["v"])
    return {n: np.asarray(g.data) for n, g in grids.items()}


def _fused(name, backend, fuse, steps=STEPS):
    k = suite.get_kernel(name)
    grids = _mk_grids(name)
    st.launch(backend=backend)(
        lambda u, v: st.timeloop(steps, swap=suite.swap_pair(name),
                                 fuse_steps=fuse)(k)(u, v))(
        grids["u"], grids["v"])
    return {n: np.asarray(g.data) for n, g in grids.items()}


# ---- fused == per-step across the whole accuracy suite (xla) --------------
@pytest.mark.parametrize("name", suite.KERNEL_NAMES)
def test_fused_matches_per_step_xla_suite(name):
    want = _per_step_reference(name)
    for fuse in (1, 2, STEPS):
        got = _fused(name, st.xla(), fuse)
        for g in ("u", "v"):
            np.testing.assert_allclose(got[g], want[g], atol=1e-6,
                                       err_msg=f"{name}/xla/fuse={fuse}/{g}")


# ---- fused == per-step on pallas(interpret) templates ---------------------
@pytest.mark.parametrize("name", ("star2d2r", "box2d1r", "star3d2r",
                                  "box3d1r", "j2d5pt", "j3d27pt"))
@pytest.mark.parametrize("template", ("gmem", "shift"))
def test_fused_matches_per_step_pallas(name, template):
    want = _per_step_reference(name)
    got = _fused(name, st.pallas(template=template), fuse=STEPS)
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6,
                                   err_msg=f"{name}/{template}/{g}")


@pytest.mark.parametrize("template", ("smem", "f4", "unroll", "semi"))
def test_fused_all_templates_star2d2r(template):
    want = _per_step_reference("star2d2r")
    got = _fused("star2d2r", st.pallas(template=template), fuse=2)
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6,
                                   err_msg=f"star2d2r/{template}/{g}")


# ---- multi-statement kernel with scalars + coefficient grids --------------
def test_fused_acoustic_matches_per_step():
    from repro.core import acoustic
    shape = (12, 12, 16)
    ref, _ = acoustic.run(shape=shape, iters=6, with_source=False)
    for backend in (st.xla(), st.pallas(template="gmem")):
        got, _ = acoustic.run(shape=shape, iters=6, with_source=False,
                              backend=backend, fuse_steps=6)
        np.testing.assert_allclose(np.asarray(got.interior),
                                   np.asarray(ref.interior), atol=1e-6)


# ---- layout invariant: ONE halo pad per grid per fusion window ------------
def test_pallas_one_pad_per_grid_per_window():
    name = "star2d1r"
    k = suite.get_kernel(name)
    codegen.reset_pad_count()
    # 12 steps in windows of 4 → 3 windows; star kernels pad u and v
    _fused(name, st.pallas(template="gmem"), fuse=4, steps=12)
    assert codegen.PAD_COUNT["u"] == 3, dict(codegen.PAD_COUNT)
    assert codegen.PAD_COUNT["v"] == 3, dict(codegen.PAD_COUNT)
    assert codegen.PAD_COUNT["total"] == 6, dict(codegen.PAD_COUNT)
    codegen.reset_pad_count()


def test_fused_window_program_has_no_pad_ops():
    """The compiled fusion-window program itself must contain zero pad ops:
    the single layout pad per grid happens eagerly at the window boundary,
    and steps inside the window write in-place in padded layout."""
    k = suite.get_kernel("star2d1r")
    halos = {g: k.info.halo for g in k.ir.grid_params}
    interior = (16, 24)
    plan = codegen.plan_pallas(k.ir, halos, interior,
                               st.pallas(template="gmem"), swap=("v", "u"))
    rng = np.random.default_rng(0)
    arrays = {g: jnp.asarray(rng.standard_normal(
        tuple(s + 2 * h for s, h in zip(interior, halos[g]))), jnp.float32)
        for g in k.ir.grid_params}
    padded = plan.to_padded(arrays)

    def window(p):
        def body(_, q):
            out = plan.step(q, {})
            return dict(out, u=out["v"], v=out["u"])
        return jax.lax.fori_loop(0, 8, body, p)

    txt = jax.jit(window).lower(padded).as_text()
    assert txt.count(" pad(") == 0, "fused window repacks the layout"


def test_fused_operands_deduplicated():
    """Each padded grid is passed once per step, not once per neighbor
    delta: the fused pallas step takes one operand per grid (+ scalars)."""
    k = suite.get_kernel("box3d2r")        # box: 27 deltas in the legacy path
    halos = {g: k.info.halo for g in k.ir.grid_params}
    plan = codegen.plan_pallas(k.ir, halos, (8, 10, 16),
                               st.pallas(template="gmem"), swap=("v", "u"))
    assert len(plan.opnd_grids) == 2       # u (input) + v (output)


# ---- window-boundary hook -------------------------------------------------
def test_between_hook_runs_at_window_boundaries():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    seen = []

    def hook(t, gs):
        seen.append(t)
        assert set(gs) == {"u", "v"}

    st.timeloop(10, swap=("v", "u"), fuse_steps=3, between=hook)(k)(
        grids["u"], grids["v"])
    assert seen == [3, 6, 9]               # not after the final window


def test_launch_fuse_steps_default_threads_to_timeloop():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    res = st.launch(backend=st.xla(), fuse_steps=2)(
        lambda u, v: st.timeloop(6, swap=("v", "u"))(k)(u, v))(
        grids["u"], grids["v"])
    assert res.value.fuse_steps == 2
    assert res.value.windows == 3


# ---- array-level API ------------------------------------------------------
def test_stencil_timeloop_array_api():
    name = "star2d2r"
    k = suite.get_kernel(name)
    want = _per_step_reference(name)
    grids = _mk_grids(name)
    arrays = {n: g.data for n, g in grids.items()}
    got = ops.stencil_timeloop(k, arrays, STEPS, swap=("v", "u"),
                               template="gmem")
    for g in ("u", "v"):
        np.testing.assert_allclose(np.asarray(got[g]), want[g], atol=1e-6)


# ---- swap validation ------------------------------------------------------
def test_swap_must_contain_output_grid():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    with pytest.raises(ValueError, match="output grid"):
        st.timeloop(2, swap=("u", "u"))(k)(grids["u"], grids["v"])


# ---- grid.randomize dtype fix ---------------------------------------------
def test_randomize_preserves_dtype():
    g = st.grid(dtype=st.bf16, shape=(8, 8), order=1).randomize(3)
    assert g.data.dtype == jnp.bfloat16
    assert g.interior.dtype == jnp.bfloat16
    # halo stays zero
    assert np.all(np.asarray(g.data, np.float32)[0] == 0)


# ---- in-kernel temporal blocking (time_block=k) ---------------------------
# shape chosen indivisible by every default block axis (8 and 128): blocks
# overhang the interior on both axes, exercising the valid-region masks
TB_SHAPE = (13, 21)


def _mk_grids_shape(name, shape, seed=0):
    k = suite.get_kernel(name)
    return {g: st.grid(dtype=st.f32, shape=shape,
                       order=k.info.order).randomize(seed + i)
            for i, g in enumerate(k.ir.grid_params)}


def _per_step_reference_shape(name, shape, steps=STEPS):
    k = suite.get_kernel(name)
    grids = _mk_grids_shape(name, shape)

    def tgt(u, v):
        for _ in range(steps):
            st.map(e=u.shape)(k)(u, v)
            (u.data, v.data) = (v.data, u.data)

    st.launch(backend=st.xla())(tgt)(grids["u"], grids["v"])
    return {n: np.asarray(g.data) for n, g in grids.items()}


@pytest.mark.parametrize("template", ("gmem", "smem", "f4", "shift",
                                      "unroll", "semi"))
@pytest.mark.parametrize("time_block", (1, 2, 4))
def test_time_block_matches_per_step_all_templates(template, time_block):
    """k steps per kernel invocation == k per-step applications, on a shape
    not divisible by the block, for every template; the outermost k·h cells
    (where the shrinking shells meet the grid halo) are checked explicitly."""
    name = "star2d2r"                      # h=2 → k·h=8 fits the 8-row block
    steps = 5                              # not a multiple of k: remainder
    want = _per_step_reference_shape(name, TB_SHAPE, steps)
    k = suite.get_kernel(name)
    grids = _mk_grids_shape(name, TB_SHAPE)
    st.launch(backend=st.pallas(template=template, time_block=time_block))(
        lambda u, v: st.timeloop(steps, swap=("v", "u"))(k)(u, v))(
        grids["u"], grids["v"])
    got = {n: np.asarray(g.data) for n, g in grids.items()}
    kh = time_block * k.info.order
    for g in ("u", "v"):
        np.testing.assert_allclose(
            got[g], want[g], atol=1e-6,
            err_msg=f"{name}/{template}/k={time_block}/{g}")
        # explicit boundary ring: outermost k·h interior cells on each side
        o = k.info.order
        for ax in range(2):
            for sl in (slice(o, o + kh), slice(-o - kh, -o or None)):
                idx = tuple(sl if a == ax else slice(None) for a in range(2))
                np.testing.assert_allclose(
                    got[g][idx], want[g][idx], atol=1e-6,
                    err_msg=f"{name}/{template}/k={time_block}/{g}/"
                            f"boundary ax{ax}")


@pytest.mark.parametrize("name", ("star2d2r", "box2d1r", "star3d2r",
                                  "box3d1r", "j2d5pt", "j3d27pt"))
def test_time_block4_matches_per_step_suite(name):
    """Acceptance: time_block=4 matches the per-step reference across the
    stencil suite (2D/3D, star/box/Jacobi)."""
    want = _per_step_reference(name)
    got = _fused(name, st.pallas(template="gmem", time_block=4), fuse=4)
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6,
                                   err_msg=f"{name}/time_block=4/{g}")


def test_time_block_acoustic_matches_per_step():
    """Multi-grid kernel (coefficient fields + scalar) through the temporal
    path."""
    from repro.core import acoustic
    shape = (12, 12, 16)
    ref, _ = acoustic.run(shape=shape, iters=6, with_source=False)
    got, _ = acoustic.run(shape=shape, iters=6, with_source=False,
                          backend=st.pallas(template="gmem", time_block=2),
                          fuse_steps=6)
    np.testing.assert_allclose(np.asarray(got.interior),
                               np.asarray(ref.interior), atol=1e-6)


def test_time_block_reduces_counted_traffic():
    """Acceptance: counted grid reads/writes per step drop ≥2× at k=4."""
    name = "star2d1r"

    def ratio(tb):
        codegen.reset_traffic_count()
        _fused(name, st.pallas(template="gmem", time_block=tb),
               fuse=8, steps=8)
        t = dict(codegen.TRAFFIC_COUNT)
        return t["grid_reads"] / t["steps"], t["grid_writes"] / t["steps"]

    r1, w1 = ratio(1)
    r4, w4 = ratio(4)
    codegen.reset_traffic_count()
    assert r1 / r4 >= 2, (r1, r4)
    assert w1 / w4 >= 2, (w1, w4)
    # the plan's static model agrees
    k = suite.get_kernel(name)
    halos = {g: k.info.halo for g in k.ir.grid_params}
    p1 = codegen.plan_pallas(k.ir, halos, (16, 24),
                             st.pallas(template="gmem"), swap=("v", "u"))
    p4 = codegen.plan_pallas(k.ir, halos, (16, 24),
                             st.pallas(template="gmem", time_block=4),
                             swap=("v", "u"))
    assert p1.grid_reads_per_step / p4.grid_reads_per_step >= 2
    assert p1.hbm_bytes_per_step() > p4.hbm_bytes_per_step()


def test_time_block_outputs_never_alias_read_windows():
    """The k>1 kernel reads k·h-deep windows that overlap *neighboring*
    blocks' output interiors; on real TPU the grid runs sequentially, so
    outputs must alias only the dedicated block-sized destination operands
    (double buffering), never the window operands — otherwise later blocks
    would fetch halo data already advanced k steps (interpret mode reads
    inputs functionally and hides the hazard)."""
    k = suite.get_kernel("star2d2r")
    halos = {g: k.info.halo for g in k.ir.grid_params}
    plan = codegen.plan_pallas(k.ir, halos, (16, 24),
                               st.pallas(template="gmem", time_block=4),
                               swap=("v", "u"))
    n_win = len(plan.opnd_grids)
    # outputs alias the destination operands appended after the windows
    assert set(plan._aliases) == {n_win, n_win + 1}, plan._aliases
    # destinations are block-sized: each program instance only donates the
    # block it writes, nothing another instance's window reads
    for i in plan._aliases:
        assert tuple(plan._in_specs[i].block_shape) == tuple(plan.B)
    # every read window keeps its expanded halo and is never aliased
    for gi, g in enumerate(plan.opnd_grids):
        assert gi not in plan._aliases
        assert tuple(plan._in_specs[gi].block_shape) == tuple(
            plan.B[ax] + 2 * plan.wf[g][ax] for ax in range(plan.ndim))
    # the double-buffered stage refuses to run without destinations
    with pytest.raises(ValueError, match="double-buffer"):
        plan.step({g: jnp.zeros(plan.padded_shape, jnp.float32)
                   for g in plan.opnd_grids}, {})
    # the k=1 plan still aliases in place — legal because its outputs are
    # center-only-tapped (window == block)
    p1 = codegen.plan_pallas(k.ir, halos, (16, 24),
                             st.pallas(template="gmem"), swap=("v", "u"))
    for gi in p1._aliases:
        assert tuple(p1._in_specs[gi].block_shape) == tuple(p1.B)


def test_defaulted_fuse_keeps_between_cadence():
    """A defaulted fuse_steps ('fuse the whole loop') must not be rounded
    to the temporal depth: steps=10, k=4 runs ONE window of 10 (two k-step
    invocations + two singles) and the between hook never fires — enabling
    time_block must not change source-injection timing."""
    name = "star2d1r"
    k = suite.get_kernel(name)
    want = _per_step_reference(name, steps=10)
    grids = _mk_grids(name)
    seen = []
    res = st.launch(backend=st.pallas(template="gmem", time_block=4))(
        lambda u, v: st.timeloop(10, swap=("v", "u"),
                                 between=lambda t, gs: seen.append(t))(k)(
            u, v))(grids["u"], grids["v"])
    assert res.value.fuse_steps == 10
    assert res.value.windows == 1
    assert seen == []
    got = {n: np.asarray(g.data) for n, g in grids.items()}
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6)


@pytest.mark.parametrize("time_block", (3, 5))
def test_time_block_odd_rotation_parity(time_block):
    """Odd temporal depths exercise the k%2 branch of the fused-loop carry
    (output names AND spare destinations must rotate together)."""
    name = "star2d1r"
    steps = 7                              # k-invocations + remainder
    want = _per_step_reference_shape(name, TB_SHAPE, steps)
    k = suite.get_kernel(name)
    grids = _mk_grids_shape(name, TB_SHAPE)
    st.launch(backend=st.pallas(template="gmem", time_block=time_block))(
        lambda u, v: st.timeloop(steps, swap=("v", "u"))(k)(u, v))(
        grids["u"], grids["v"])
    got = {n: np.asarray(g.data) for n, g in grids.items()}
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6,
                                   err_msg=f"k={time_block}/{g}")


def test_explicit_whole_loop_fuse_not_rounded():
    """An explicit fuse_steps >= steps covers the whole loop and must not
    be rounded to the temporal depth either — same cadence invariant as
    the defaulted window."""
    name = "star2d1r"
    k = suite.get_kernel(name)
    grids = _mk_grids(name)
    seen = []
    res = st.launch(backend=st.pallas(template="gmem", time_block=4))(
        lambda u, v: st.timeloop(10, swap=("v", "u"), fuse_steps=16,
                                 between=lambda t, gs: seen.append(t))(k)(
            u, v))(grids["u"], grids["v"])
    assert res.value.fuse_steps == 10
    assert res.value.windows == 1
    assert seen == []


def test_autotune_expansion_keeps_user_time_block():
    """A user-pinned time_block on a plain space entry must be measured,
    not silently overwritten by the time_block_space expansion."""
    b = st.pallas(template="gmem", time_block=8)
    cands = autotune._normalize_space([b], 2, (16, 24), ("v", "u"),
                                      steps=8, fuse_space=(8,),
                                      time_block_space=(1, 2))
    tbs = [getattr(bb, "time_block", 1) for bb, _ in cands]
    assert tbs == [8, 1, 2], cands


def test_distributed_window_decomposition_keeps_inner_depth():
    """A distributed window indivisible by the inner temporal depth must
    split into (largest multiple, remainder) sub-programs — not silently
    run the whole window with the depth disabled."""
    from repro.core import timeloop as tl
    assert tl.window_parts(10, 4) == [8, 2]
    assert tl.window_parts(8, 4) == [8]       # exact multiple: one program
    assert tl.window_parts(3, 4) == [3]       # below the depth: as-is
    assert tl.window_parts(10, 1) == [10]     # no inner depth
    assert tl.window_parts(9, 4) == [8, 1]    # single-step remainder


def test_autotune_norm_fuse_matches_engine_window():
    """Autotune normalizes candidate windows exactly like the engine
    (shared timeloop.normalize_fuse): requests ≥ steps collapse to one
    whole-loop window and deduplicate; sub-loop windows are honored as
    requested (never rounded to the temporal depth)."""
    b = st.distributed(inner=st.pallas(template="gmem", time_block=2))
    cands = autotune._normalize_space(
        [b, (b, 9)], 2, (16, 24), ("v", "u"), steps=8, fuse_space=(8,))
    # expansion gives (b, 8); the explicit pair collapses 9 -> 8 (whole
    # loop) and deduplicates against it
    assert [f for _, f in cands] == [8], cands
    p = st.pallas(template="gmem", time_block=4)
    cands = autotune._normalize_space(
        [(p, 6)], 2, (16, 24), ("v", "u"), steps=20, fuse_space=())
    assert [f for _, f in cands] == [6], cands   # not rounded to 4


def test_time_block_one_pad_per_grid_per_window():
    """Temporal blocking keeps the one-pad-per-window layout invariant."""
    codegen.reset_pad_count()
    _fused("star2d1r", st.pallas(template="gmem", time_block=2),
           fuse=4, steps=12)
    assert codegen.PAD_COUNT["u"] == 3, dict(codegen.PAD_COUNT)
    assert codegen.PAD_COUNT["v"] == 3, dict(codegen.PAD_COUNT)
    codegen.reset_pad_count()


def test_time_block_halo_growth_block_geometry():
    """Default block geometry grows so the k·h expanded halo fits."""
    k = suite.get_kernel("star2d4r")       # h=4; k=4 → k·h=16 > default 8
    halos = {g: k.info.halo for g in k.ir.grid_params}
    plan = codegen.plan_pallas(k.ir, halos, (32, 32),
                               st.pallas(template="gmem", time_block=4),
                               swap=("v", "u"))
    assert plan.B[0] >= 16
    assert plan.wf["u"] == (16, 16)


def test_time_block_validation():
    k = suite.get_kernel("star2d2r")
    halos = {g: k.info.halo for g in k.ir.grid_params}
    # user-pinned block too small for k·h
    with pytest.raises(ValueError, match="k·h <= block"):
        codegen.plan_pallas(k.ir, halos, (16, 24),
                            st.pallas(template="gmem", time_block=8,
                                      block=(8, 128)), swap=("v", "u"))
    # temporal blocking needs the leapfrog swap pair
    with pytest.raises(ValueError, match="swap"):
        codegen.plan_pallas(k.ir, halos, (16, 24),
                            st.pallas(template="gmem", time_block=2))
    # the per-application path advances one step
    grids = _mk_grids("star2d2r")
    with pytest.raises(ValueError, match="fused time-loop"):
        st.launch(backend=st.pallas(template="gmem", time_block=2))(
            lambda u, v: st.map(e=u.shape)(k)(u, v))(grids["u"], grids["v"])
    with pytest.raises(ValueError):
        st.pallas(time_block=0)
    # a launch-level override that cannot apply must not be silently
    # ignored (the user would measure the plain fused loop believing the
    # temporal depth is active)
    g2 = _mk_grids("star2d2r")
    with pytest.raises(ValueError, match="pallas backend"):
        st.launch(backend=st.xla(), time_block=2)(
            lambda u, v: st.timeloop(2, swap=("v", "u"))(k)(u, v))(
            g2["u"], g2["v"])


def test_launch_time_block_override_honors_window():
    """st.launch(time_block=k) overrides the backend knob; the requested
    fusion window is honored exactly (each window runs ⌊kw/k⌋ k-step
    invocations plus single-step remainder), never rounded to k."""
    name = "star2d1r"
    k = suite.get_kernel(name)
    want = _per_step_reference(name, steps=10)
    grids = _mk_grids(name)
    seen = []
    res = st.launch(backend=st.pallas(template="gmem"), time_block=2)(
        lambda u, v: st.timeloop(10, swap=("v", "u"), fuse_steps=3,
                                 between=lambda t, gs: seen.append(t))(k)(
            u, v))(grids["u"], grids["v"])
    assert res.value.fuse_steps == 3       # cadence exactly as requested
    assert res.value.windows == 4
    assert seen == [3, 6, 9]
    got = {n: np.asarray(g.data) for n, g in grids.items()}
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6)


def test_time_block_never_stretches_between_cadence():
    """fuse_steps below the temporal depth is honored (runs as single
    steps): the between hook keeps its exact per-window cadence."""
    name = "star2d1r"
    k = suite.get_kernel(name)
    want = _per_step_reference(name, steps=4)
    grids = _mk_grids(name)
    seen = []
    res = st.launch(backend=st.pallas(template="gmem", time_block=4))(
        lambda u, v: st.timeloop(4, swap=("v", "u"), fuse_steps=1,
                                 between=lambda t, gs: seen.append(t))(k)(
            u, v))(grids["u"], grids["v"])
    assert res.value.fuse_steps == 1
    assert seen == [1, 2, 3]
    got = {n: np.asarray(g.data) for n, g in grids.items()}
    for g in ("u", "v"):
        np.testing.assert_allclose(got[g], want[g], atol=1e-6)


def test_autotune_searches_time_block():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    autotune.clear_cache()
    res = autotune.tune(k, grids, iters=1,
                        space=[st.pallas(template="gmem")],
                        swap=("v", "u"), steps=8, fuse_space=(8,),
                        time_block_space=(1, 2))
    assert len(res.trials) == 2
    tbs = {getattr(b, "time_block", 1) for b, _, _ in res.trials}
    assert tbs == {1, 2}
    assert res.seconds < float("inf")
    # winner is launchable with its time_block riding on the backend
    g2 = _mk_grids("star2d1r")
    st.launch(backend=res.backend, fuse_steps=res.fuse_steps)(
        lambda u, v: st.timeloop(4, swap=("v", "u"))(k)(u, v))(
        g2["u"], g2["v"])
    autotune.clear_cache()


def test_autotune_dedups_overlapping_space():
    """A custom space overlapping the fuse/time_block expansion must not
    measure the same (backend, fuse_steps) twice."""
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    autotune.clear_cache()
    res = autotune.tune(
        k, grids, iters=1,
        space=[st.pallas(template="gmem"),
               (st.pallas(template="gmem", time_block=2), 4)],
        swap=("v", "u"), steps=8, fuse_space=(4,),
        time_block_space=(1, 2))
    # expansion: (tb=1, 4), (tb=2, 4); the explicit pair duplicates the
    # latter → 2 unique candidates, not 3
    assert len(res.trials) == 2, [(b, f) for b, f, _ in res.trials]
    autotune.clear_cache()


# ---- autotune cache key + fuse_steps search -------------------------------
def test_autotune_cache_key_includes_space_and_iters():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    autotune.clear_cache()
    a = autotune.tune(k, grids, iters=1, space=[st.xla()])
    b = autotune.tune(k, grids, iters=1,
                      space=[st.pallas(template="gmem")])
    assert a.backend.kind == "xla"
    assert b.backend.kind == "pallas"      # not the stale cached xla result
    assert autotune.tune(k, grids, iters=1, space=[st.xla()]) is a  # memoized
    autotune.clear_cache()
    assert autotune.tune(k, grids, iters=1, space=[st.xla()]) is not a


def test_autotune_searches_fuse_steps():
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")
    autotune.clear_cache()
    res = autotune.tune(k, grids, iters=1, space=[st.xla()],
                        swap=("v", "u"), steps=8, fuse_space=(1, 8))
    assert len(res.trials) == 2
    assert res.fuse_steps in (1, 8)
    assert res.seconds < float("inf")
    # tuner result is launchable through the fused path
    g2 = _mk_grids("star2d1r")
    st.launch(backend=res.backend, fuse_steps=res.fuse_steps)(
        lambda u, v: st.timeloop(4, swap=("v", "u"))(k)(u, v))(
        g2["u"], g2["v"])
    autotune.clear_cache()


def test_launch_autotune_picks_backend_and_fuse():
    """st.launch(autotune=True) replaces the fixed backend with the tuned
    winner and applies the tuned window when fuse is unspecified."""
    autotune.clear_cache()
    autotune.reset_measure_count()
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")

    def tgt(u, v):
        return st.timeloop(8, swap=("v", "u"))(k)(u, v)

    run = st.launch(autotune=True, autotune_space=[st.xla()],
                    autotune_steps=4, autotune_fuse_space=(1, 4),
                    autotune_time_block_space=(1,))
    res = run(tgt)(grids["u"], grids["v"])
    # 2 candidates <= default top_k=3: no pruning, both measured
    assert autotune.MEASURE_COUNT["measured_candidates"] == 2
    assert autotune.MEASURE_COUNT["pruned_candidates"] == 0
    assert res.value.fuse_steps in (1, 4, 8)
    # a second launch hits the in-process tune cache
    g2 = _mk_grids("star2d1r")
    run(tgt)(g2["u"], g2["v"])
    assert autotune.MEASURE_COUNT["measured_candidates"] == 2
    autotune.clear_cache()


def test_launch_autotune_prunes_with_injected_model():
    from repro.core import cost_model as cm
    autotune.clear_cache()
    autotune.reset_measure_count()
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")

    def tgt(u, v):
        return st.timeloop(8, swap=("v", "u"))(k)(u, v)

    run = st.launch(autotune=True,
                    autotune_space=[st.xla(), st.pallas(template="gmem")],
                    autotune_top_k=2, autotune_steps=4,
                    autotune_fuse_space=(1, 2, 4),
                    autotune_time_block_space=(1, 2),
                    autotune_cost_model=cm.CostModel(calibrate=False))
    run(tgt)(grids["u"], grids["v"])
    # 9 candidates, shortlist of 2
    assert autotune.MEASURE_COUNT["measured_candidates"] == 2
    assert autotune.MEASURE_COUNT["pruned_candidates"] == 7
    autotune.clear_cache()


def test_launch_autotune_explicit_fuse_wins():
    autotune.clear_cache()
    k = suite.get_kernel("star2d1r")
    grids = _mk_grids("star2d1r")

    def tgt(u, v):
        return st.timeloop(8, swap=("v", "u"), fuse_steps=2)(k)(u, v)

    run = st.launch(autotune=True, autotune_space=[st.xla()],
                    autotune_steps=4, autotune_fuse_space=(1, 4),
                    autotune_time_block_space=(1,))
    res = run(tgt)(grids["u"], grids["v"])
    assert res.value.fuse_steps == 2   # timeloop's own fuse overrides
    autotune.clear_cache()


def test_launch_autotune_skips_batched_timeloop():
    """Batched grids fall through to the fixed backend unchanged."""
    autotune.clear_cache()
    autotune.reset_measure_count()
    k = suite.get_kernel("star2d1r")
    grids = {g: st.grid(st.f32, (8, 8), k.info.order, batch=2).randomize(i)
             for i, g in enumerate(k.ir.grid_params)}

    def tgt(u, v):
        return st.timeloop(4, swap=("v", "u"))(k)(u, v)

    run = st.launch(autotune=True, autotune_space=[st.xla()])
    run(tgt)(grids["u"], grids["v"])
    assert autotune.MEASURE_COUNT["measured_candidates"] == 0
    autotune.clear_cache()
