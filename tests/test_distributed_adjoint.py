"""Distributed adjoint tests: the shard_mapped backward wave propagation.

The backward pass of a distributed engine replays each checkpointed
segment through the engine's own fused shard_map window programs and
pulls cotangents through a second shard_map program whose halo exchanges
are the reverse ``ppermute``s of the forward ones
(``HaloSpec.transpose`` geometry).  These tests pin, on a forced
4-host-device mesh:

  * gradient vs central finite differences (<1e-3 rel err, f64) across
    ``time_steps`` × inner ``time_block`` exchange-depth combinations,
  * primal bit-for-bit equality and gradient equality with the
    single-device (xla) adjoint on the same problem,
  * sharded coefficient-grid (velocity model) and per-scenario scalar
    gradients under batching,
  * masked-cell freezing in the sharded adjoint (vs the batched xla
    masked adjoint),
  * resume-mid-backward resilience (``run_resilient(loss=...)`` +
    ``FailureInjector``) bit-exact with an uninterrupted run.

They must see >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the main test
process keeps the default single device, per the dry-run contract)."""
import os
import subprocess
import sys
import tempfile
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    # a real file (not -c) so the DSL frontend can inspect.getsource
    # kernels defined inside the test body
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(textwrap.dedent(code))
        path = f.name
    try:
        r = subprocess.run([sys.executable, path], capture_output=True,
                           text=True, env=env, timeout=900)
    finally:
        os.unlink(path)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# shared prelude: f64, a 4-device mesh, engines over star2d2r, and an
# interior-only loss (the distributed carry convention keeps grid-halo
# cells fixed at zero and never rotates them, so only interiors are
# comparable across backends — and only interiors are physics)
PRELUDE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import adjoint, dsl as st, suite
from repro.core import timeloop as tl

assert len(jax.devices()) == 4, jax.devices()
MESH = jax.make_mesh((4,), ("data",))
K = suite.get_kernel("star2d2r")
SHAPE = (16, 12)
O = K.info.order
SWAP = suite.swap_pair(K.name)

def make_arrays(dtype=jnp.float64, batch=0, seed=0):
    gs = {g: st.grid(dtype=dtype, shape=SHAPE, order=O,
                     batch=batch or None).randomize(i + seed)
          for i, g in enumerate(K.ir.grid_params)}
    halos = {n: g.halo for n, g in gs.items()}
    return {n: jnp.asarray(g.data, dtype) for n, g in gs.items()}, halos

def engine(be, halos, batch=0):
    return tl.TimeloopEngine(K.ir, halos, SHAPE, be, swap=SWAP, mesh=MESH,
                             batch=batch, differentiable=True)

def idx(batch=0):
    return (slice(None),) * (1 if batch else 0) \\
        + tuple(slice(O, O + s) for s in SHAPE)

def interior_loss(fn, scal, batch=0):
    ix = idx(batch)
    def loss(arrs):
        out = fn(arrs, scal)
        return sum(jnp.sum(out[g][ix] ** 2) for g in SWAP)
    return loss

def check_fd(fn, arrays, scal, tag, batch=0, n_probes=2, eps=1e-6,
             rtol=1e-3):
    loss = interior_loss(fn, scal, batch)
    grad = jax.grad(loss)(arrays)
    rng = np.random.default_rng(7)
    for g, a in arrays.items():
        a = np.asarray(a)
        for _ in range(n_probes):
            ix = ((int(rng.integers(0, a.shape[0])),) if batch else ()) \\
                + tuple(int(rng.integers(O, O + s)) for s in SHAPE)
            ap, am = a.copy(), a.copy()
            ap[ix] += eps
            am[ix] -= eps
            fd = (float(loss({**arrays, g: jnp.asarray(ap)}))
                  - float(loss({**arrays, g: jnp.asarray(am)}))) / (2 * eps)
            ad = float(np.asarray(grad[g])[ix])
            err = abs(ad - fd) / max(abs(fd), abs(ad), 1e-8)
            assert err < rtol, (tag, g, ix, ad, fd, err)
"""


def test_grad_vs_fd_across_exchange_depths():
    """Central-FD gradient checks on the 4-device mesh across the
    exchange-depth grid: per-step exchanges (1,1), device-level time
    skewing (2,1), and inner temporal blocking (1,2) — each with a
    fuse window that exercises both the fori_loop group path and an
    unrolled remainder group."""
    _run_in_subprocess(PRELUDE + """
for ts, tb in ((1, 1), (2, 1), (1, 2)):
    inner = st.pallas(time_block=tb) if tb > 1 else st.xla()
    be = st.distributed(grid_axes=("data", None), time_steps=ts,
                        inner=inner)
    arrays, halos = make_arrays()
    eng = engine(be, halos)
    fn = adjoint.differentiable_run(eng, 5)   # fuse 3 -> windows (3, 2)
    check_fd(fn, arrays, {}, f"depth {ts}x{tb}")
    print("OK fd", ts, "x", tb)
""")


def test_matches_single_device_adjoint():
    """Primal interiors bit-for-bit (per-step exchange schedule) and
    gradients to machine precision against the single-device xla adjoint
    on the same problem.  Depth-2 time skewing recomputes boundary shells
    redundantly — a different XLA fusion schedule whose last-bit
    reassociation may differ — so it is pinned at 1-ulp instead."""
    _run_in_subprocess(PRELUDE + """
arrays, halos = make_arrays()
eng_x = tl.TimeloopEngine(K.ir, halos, SHAPE, st.xla(), swap=SWAP,
                          differentiable=True)
fn_x = adjoint.differentiable_run(eng_x, 6, fuse_steps=2)
ix = idx()
out_x = fn_x(arrays, {})
g_x = jax.grad(interior_loss(fn_x, {}))(arrays)

for ts in (1, 2):
    be = st.distributed(grid_axes=("data", None), time_steps=ts)
    fn_d = adjoint.differentiable_run(engine(be, halos), 6, fuse_steps=2)
    out_d = fn_d(arrays, {})
    for g in K.ir.grid_params:
        a, b = np.asarray(out_d[g][ix]), np.asarray(out_x[g][ix])
        if ts == 1:
            assert np.array_equal(a, b), g      # bit-for-bit
        else:
            np.testing.assert_allclose(a, b, rtol=1e-14, atol=1e-15,
                                       err_msg=g)
    print("OK primal", "bit-exact" if ts == 1 else "1-ulp", "ts", ts)

    g_d = jax.grad(interior_loss(fn_d, {}))(arrays)
    for g in K.ir.grid_params:
        np.testing.assert_allclose(np.asarray(g_d[g][ix]),
                                   np.asarray(g_x[g][ix]),
                                   rtol=1e-9, atol=1e-12, err_msg=g)
    print("OK grads match single-device ts", ts)
""")


def test_sharded_coefficient_and_scalar_grads_batched():
    """The FWI surface under sharding: gradients reach a sharded
    coefficient grid (velocity-model analogue) and per-scenario scalars
    of a batched distributed engine, matching the batched xla adjoint;
    per-scenario gradients stay isolated."""
    _run_in_subprocess("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import adjoint, dsl as st
from repro.core import timeloop as tl

MESH = jax.make_mesh((4,), ("data",))

@st.kernel
def heat(u: st.grid, v: st.grid, c: st.grid, a: st.f32):
    v.at(0, 0).set(u.at(0, 0) + a * c.at(0, 0) * (
        u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1)
        - 4.0 * u.at(0, 0)))

B, SHAPE, STEPS = 2, (16, 10), 4
grids = {g: st.grid(dtype=jnp.float64, shape=SHAPE, order=1,
                    batch=B).randomize(i)
         for i, g in enumerate(("u", "v", "c"))}
halos = {n: g.halo for n, g in grids.items()}
arrays = {n: jnp.asarray(g.data) for n, g in grids.items()}
scal = {"a": jnp.asarray([0.1, 0.15])}          # per-scenario scalar
ix = (slice(None),) + tuple(slice(1, 1 + s) for s in SHAPE)

def build(backend, mesh):
    eng = tl.TimeloopEngine(heat.ir, halos, SHAPE, backend, swap=("v", "u"),
                            mesh=mesh, batch=B, differentiable=True)
    return adjoint.differentiable_run(eng, STEPS, fuse_steps=2)

fn_d = build(st.distributed(grid_axes=("data", None)), MESH)
fn_x = build(st.xla(), None)

def loss_of(fn):
    return lambda a_, s_: jnp.sum(fn(a_, s_)["v"][ix] ** 2)

ga_d, gs_d = jax.grad(loss_of(fn_d), argnums=(0, 1))(arrays, scal)
ga_x, gs_x = jax.grad(loss_of(fn_x), argnums=(0, 1))(arrays, scal)
for g in arrays:
    np.testing.assert_allclose(np.asarray(ga_d[g][ix]),
                               np.asarray(ga_x[g][ix]),
                               rtol=1e-9, atol=1e-12, err_msg=g)
assert float(jnp.linalg.norm(ga_d["c"][ix])) > 0   # velocity grid gets grad
np.testing.assert_allclose(np.asarray(gs_d["a"]), np.asarray(gs_x["a"]),
                           rtol=1e-9)
assert np.asarray(gs_d["a"]).shape == (B,)          # per-scenario
print("OK sharded coeff+scalar grads")

# per-scenario isolation: a loss over scenario 1 only leaves scenario 0
# gradients exactly zero
g1 = jax.grad(lambda a_: jnp.sum(fn_d(a_, scal)["v"][1][1:-1, 1:-1] ** 2))(
    arrays)
assert float(jnp.linalg.norm(g1["u"][0])) == 0.0
assert float(jnp.linalg.norm(g1["u"][1])) > 0.0
print("OK per-scenario isolation")
""")


def test_masked_freeze_under_sharding():
    """Masked serving windows under sharding: the distributed masked
    adjoint freezes masked cells and budget-exhausted scenarios exactly
    like the batched xla masked adjoint."""
    _run_in_subprocess(PRELUDE + """
B, STEPS = 2, 4
arrays, halos = make_arrays(batch=B)
mask = np.ones((B,) + SHAPE, bool)
mask[1, :, 6:] = False                  # scenario 1: right half frozen
limits = np.asarray([STEPS, 2], np.int32)   # scenario 1 stops at step 2

be = st.distributed(grid_axes=("data", None))
fn_d = adjoint.differentiable_run(engine(be, halos, batch=B), STEPS,
                                  fuse_steps=2,
                                  domain_mask=jnp.asarray(mask),
                                  step_limits=jnp.asarray(limits))
eng_x = tl.TimeloopEngine(K.ir, halos, SHAPE, st.xla(), swap=SWAP,
                          batch=B, differentiable=True)
fn_x = adjoint.differentiable_run(eng_x, STEPS, fuse_steps=2,
                                  domain_mask=jnp.asarray(mask),
                                  step_limits=jnp.asarray(limits))

ix = idx(batch=B)
out_d, out_x = fn_d(arrays, {}), fn_x(arrays, {})
for g in K.ir.grid_params:
    assert np.array_equal(np.asarray(out_d[g][ix]),
                          np.asarray(out_x[g][ix])), g
g_d = jax.grad(interior_loss(fn_d, {}, batch=B))(arrays)
g_x = jax.grad(interior_loss(fn_x, {}, batch=B))(arrays)
for g in K.ir.grid_params:
    np.testing.assert_allclose(np.asarray(g_d[g][ix]),
                               np.asarray(g_x[g][ix]),
                               rtol=1e-9, atol=1e-12, err_msg=g)
print("OK masked adjoint matches xla")

# a frozen cell deep inside the masked half passes through untouched, so
# its gradient is exactly 2*value (identity through every window)
out = fn_d(arrays, {})
frozen = (1, O + 4, O + 8)
for g in SWAP:
    np.testing.assert_allclose(
        float(np.asarray(g_d[g])[frozen]),
        2.0 * float(np.asarray(out[g])[frozen]), rtol=1e-12)
print("OK frozen-cell identity")

check_fd(fn_d, arrays, {}, "masked", batch=B, n_probes=1)
print("OK masked fd")
""")


def test_resume_mid_backward_resilience(tmp_path):
    """A distributed backward pass killed mid-segment resumes from the
    on-disk snapshot and produces the same value and gradients — and the
    uninterrupted resilient run equals the plain in-memory adjoint."""
    _run_in_subprocess(PRELUDE + f"""
from repro.train.fault_tolerance import FailureInjector

STEPS, FUSE = 6, 2          # W=3 windows, stride 1 -> 3 backward segments
be = st.distributed(grid_axes=("data", None), time_steps=2)
arrays, halos = make_arrays()
ix = idx()

def loss(arrs):
    return jnp.sum(arrs["v"][ix] ** 2)

ref = tl.run_resilient(engine(be, halos), dict(arrays), {{}}, STEPS, FUSE,
                       ckpt_dir={str(tmp_path / 'ok')!r}, loss=loss)

# unit 5 is the second backward segment (units: 0-2 fwd, 3 seed, 4-6 bwd)
got = tl.run_resilient(engine(be, halos), dict(arrays), {{}}, STEPS, FUSE,
                       ckpt_dir={str(tmp_path / 'fail')!r}, loss=loss,
                       injector=FailureInjector([5]))

assert np.array_equal(np.asarray(ref["value"]), np.asarray(got["value"]))
for g in ref["grad_arrays"]:
    assert np.array_equal(np.asarray(ref["grad_arrays"][g]),
                          np.asarray(got["grad_arrays"][g])), g
print("OK resume-mid-backward bit-exact")

# the uninterrupted resilient gradient equals the in-memory adjoint
fn = adjoint.differentiable_run(engine(be, halos), STEPS, fuse_steps=FUSE)
want_v, want_g = jax.value_and_grad(lambda a: loss(fn(a, {{}})))(arrays)
assert float(want_v) == float(ref["value"])
for g in want_g:
    np.testing.assert_allclose(np.asarray(ref["grad_arrays"][g]),
                               np.asarray(want_g[g]), rtol=1e-12, atol=0)
print("OK resilient == in-memory adjoint")
""")
