"""Hypothesis property tests over the stencil system's invariants.

Random linear stencils are synthesized as DSL source, run through the full
frontend → codegen path, and checked against the oracle; linearization is
checked to be evaluation-preserving.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt); property tests skipped")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core import analysis, dsl as st, lowering
from repro.kernels.stencil import ops, ref


def _synth_kernel(ndim, taps_and_coeffs, name="prop_k"):
    terms = []
    for offs, c in taps_and_coeffs:
        o = ", ".join(str(x) for x in offs)
        terms.append(f"{c!r} * u.at({o})")
    body = " + ".join(terms) if terms else "0.0 * u.at(" + ", ".join(
        "0" for _ in range(ndim)) + ")"
    center = ", ".join("0" for _ in range(ndim))
    src = (f"def {name}(u: st.grid, v: st.grid):\n"
           f"    v.at({center}).set({body})\n")
    ns = {"st": st}
    exec(compile(src, "<prop>", "exec"), ns)  # noqa: S102
    fn = ns[name]
    fn.__stencil_source__ = src
    return st.kernel(fn)


@hst.composite
def random_stencil(draw):
    ndim = draw(hst.sampled_from([2, 3]))
    n_taps = draw(hst.integers(1, 8))
    taps = set()
    for _ in range(n_taps):
        taps.add(tuple(draw(hst.integers(-3, 3)) for _ in range(ndim)))
    coeffs = [round(draw(hst.floats(-2, 2, allow_nan=False,
                                    allow_infinity=False)), 4)
              for _ in taps]
    return ndim, list(zip(sorted(taps), coeffs))


@settings(max_examples=12, deadline=None)
@given(random_stencil(), hst.sampled_from(["gmem", "shift", "semi"]))
def test_random_linear_stencils_match_oracle(spec, template):
    ndim, tc = spec
    k = _synth_kernel(ndim, tc)
    interior = (14, 22) if ndim == 2 else (9, 11, 17)
    h = k.info.halo
    halos = {g: h for g in k.ir.grid_params}
    rng = np.random.default_rng(0)
    arrays = {g: jnp.asarray(
        rng.standard_normal(tuple(s + 2 * hh for s, hh in zip(interior, h))),
        jnp.float32) for g in k.ir.grid_params}
    want = ref.reference_apply(k.ir, halos, interior, dict(arrays))
    got = ops.stencil_apply(k, dict(arrays), halos=halos, template=template)
    np.testing.assert_allclose(np.asarray(got["v"]), np.asarray(want["v"]),
                               atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(random_stencil())
def test_linearize_preserves_semantics(spec):
    ndim, tc = spec
    k = _synth_kernel(ndim, tc)
    stmts = analysis.inline_locals(k.ir)
    terms, const = analysis.linearize(stmts[0].expr)

    # evaluate both forms at a random point-sample (taps → random scalars)
    rng = np.random.default_rng(1)
    vals = {}

    def read(g, offs):
        key = (g, offs)
        if key not in vals:
            vals[key] = float(rng.standard_normal())
        return vals[key]

    direct = lowering.eval_expr(stmts[0].expr, read, {}, {})
    linear = lowering.eval_expr(const, read, {}, {})
    for (g, offs), c in terms.items():
        linear = linear + lowering.eval_expr(c, read, {}, {}) * read(g, offs)
    assert abs(float(direct) - float(linear)) < 1e-4 * max(1.0, abs(float(direct)))


@settings(max_examples=10, deadline=None)
@given(hst.integers(0, 2 ** 31 - 1))
def test_grid_roundtrip(seed):
    g = st.grid(dtype=st.f32, shape=(6, 7), order=2).randomize(seed)
    inner = np.asarray(g.interior)
    assert inner.shape == (6, 7)
    # halo stays zero after randomize
    full = np.asarray(g.data)
    assert full.shape == (10, 11)
    assert np.all(full[:2] == 0) and np.all(full[-2:] == 0)
    g2 = st.grid(dtype=st.f32, shape=(6, 7), order=2)
    g2.interior = inner
    np.testing.assert_array_equal(np.asarray(g2.data), full)
