"""Pallas stencil kernel validation: every template vs the pure-jnp oracle.

Sweeps the paper Table 4 suite across templates, dtypes, block shapes and
sub-regions (interpret mode executes the kernel bodies on CPU).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsl as st, suite
from repro.kernels.stencil import ops, ref

SHAPE_2D = (24, 40)
SHAPE_3D = (12, 16, 20)
ALL_TEMPLATES = ("gmem", "smem", "f4", "shift", "unroll", "semi")


def _mk(kernel, interior, dtype=jnp.float32, seed=0, halos=None):
    rng = np.random.default_rng(seed)
    halos = halos or {g: kernel.info.halo for g in kernel.ir.grid_params}
    arrays = {}
    for g in kernel.ir.grid_params:
        full = tuple(s + 2 * h for s, h in zip(interior, halos[g]))
        arrays[g] = jnp.asarray(rng.standard_normal(full), dtype)
    return arrays, halos


def _check(kernel, template, interior, dtype=jnp.float32, block=None,
           mem_type=None, region=None, atol=None):
    arrays, halos = _mk(kernel, interior, dtype)
    want = ref.reference_apply(kernel.ir, halos, interior, dict(arrays),
                               region=region)
    got = ops.stencil_apply(kernel, dict(arrays), halos=halos,
                            template=template, block=block, mem_type=mem_type,
                            region=region)
    if atol is None:
        atol = 1e-5 if dtype == jnp.float32 else 1e-1
    for g in kernel.ir.output_grids():
        np.testing.assert_allclose(
            np.asarray(got[g], np.float32), np.asarray(want[g], np.float32),
            atol=atol, err_msg=f"{kernel.name}/{template}/{g}")


# ---- full suite on two contrasting templates ------------------------------
@pytest.mark.parametrize("name", suite.KERNEL_NAMES)
@pytest.mark.parametrize("template", ("gmem", "semi"))
def test_suite_kernels(name, template):
    k = suite.get_kernel(name)
    interior = SHAPE_2D if k.info.ndim == 2 else SHAPE_3D
    _check(k, template, interior)


# ---- representative kernels on every template -----------------------------
@pytest.mark.parametrize("name", ("star2d4r", "star3d4r", "box2d2r", "box3d2r"))
@pytest.mark.parametrize("template", ALL_TEMPLATES)
def test_all_templates(name, template):
    k = suite.get_kernel(name)
    interior = SHAPE_2D if k.info.ndim == 2 else SHAPE_3D
    _check(k, template, interior)


# ---- dtype sweep -----------------------------------------------------------
@pytest.mark.parametrize("dtype", (jnp.float32, jnp.bfloat16))
@pytest.mark.parametrize("template", ("gmem", "shift"))
def test_dtypes(dtype, template):
    _check(suite.get_kernel("star3d2r"), template, SHAPE_3D, dtype=dtype)


# ---- block-shape sweep (the paper's Dx/Dy/Dz knobs) ------------------------
@pytest.mark.parametrize("block", ((8, 8, 128), (8, 16, 128), (16, 8, 256)))
def test_block_shapes_3d(block):
    _check(suite.get_kernel("star3d4r"), "gmem", (20, 24, 40), block=block)


@pytest.mark.parametrize("block", ((8, 128), (16, 256)))
@pytest.mark.parametrize("template", ("smem", "unroll"))
def test_block_shapes_2d(block, template):
    _check(suite.get_kernel("star2d3r"), template, (30, 50), block=block)


# ---- mem_type (registers vs vmem streaming) --------------------------------
@pytest.mark.parametrize("mem_type", ("registers", "vmem"))
def test_stream_mem_types(mem_type):
    _check(suite.get_kernel("box3d1r"), "shift", SHAPE_3D, mem_type=mem_type)


# ---- sub-region application (PML-style two-region decomposition) ----------
def test_region_2d():
    k = suite.get_kernel("star2d2r")
    region = ((4, 20), (8, 32))
    _check(k, "gmem", SHAPE_2D, region=region)


def test_region_3d_thin_slab():
    k = suite.get_kernel("star3d1r")
    region = ((0, 3), (0, 16), (0, 20))  # a PML face
    _check(k, "gmem", SHAPE_3D, region=region)


# ---- multi-statement + scalar + per-grid halos (acoustic-ISO pattern) -----
@st.kernel
def _wave(u: st.grid, v: st.grid, vp: st.grid, dt2: st.f32):
    lap = (-2.847 * u.at(0, 0, 0)
           + 1.6 * (u.at(-1, 0, 0) + u.at(1, 0, 0) + u.at(0, -1, 0)
                    + u.at(0, 1, 0) + u.at(0, 0, -1) + u.at(0, 0, 1))
           - 0.2 * (u.at(-2, 0, 0) + u.at(2, 0, 0) + u.at(0, -2, 0)
                    + u.at(0, 2, 0) + u.at(0, 0, -2) + u.at(0, 0, 2)))
    v.at(0, 0, 0).set(2.0 * u.at(0, 0, 0) - v.at(0, 0, 0)
                      + dt2 * vp.at(0, 0, 0) * lap)


@pytest.mark.parametrize("template", ALL_TEMPLATES)
def test_multistatement_scalar_kernel(template):
    interior = (12, 10, 24)
    halos = {"u": (2, 2, 2), "v": (0, 0, 0), "vp": (0, 0, 0)}
    rng = np.random.default_rng(3)
    arrays = {g: jnp.asarray(
        rng.standard_normal(tuple(s + 2 * h for s, h in zip(interior, halos[g]))),
        jnp.float32) for g in ("u", "v", "vp")}
    scal = {"dt2": 0.002}
    want = ref.reference_apply(_wave.ir, halos, interior, dict(arrays), scal)
    got = ops.stencil_apply(_wave, dict(arrays), scal, halos=halos,
                            template=template)
    np.testing.assert_allclose(np.asarray(got["v"]), np.asarray(want["v"]),
                               atol=1e-5)


# ---- iterated application stays consistent across backends ----------------
def test_iterated_swap_consistency():
    k = suite.get_kernel("star2d1r")
    u0 = np.random.default_rng(7).standard_normal((18, 18)).astype(np.float32)

    def run(backend):
        u = st.grid(dtype=st.f32, shape=(16, 16), order=1)
        v = st.grid(dtype=st.f32, shape=(16, 16), order=1)
        u.data = jnp.asarray(u0)
        v.data = jnp.zeros_like(u.data)

        def tgt(u, v):
            for _ in range(5):
                st.map(e=u.shape)(k)(u, v)
                (v, u) = (u, v)
            return u

        return np.asarray(st.launch(backend=backend)(tgt)(u, v).value.interior)

    a = run(st.xla())
    b = run(st.pallas(template="gmem"))
    c = run(st.pallas(template="shift"))
    np.testing.assert_allclose(a, b, atol=1e-5)
    np.testing.assert_allclose(a, c, atol=1e-5)
