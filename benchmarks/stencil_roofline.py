"""Analytic HBM-traffic model for the Pallas stencil templates (§Perf
stencil iteration 3).

On-TPU the generated kernel's HBM traffic is set by its BlockSpec geometry
(every input block fetched HBM→VMEM once per grid step, output written
once) — this is statically known, so the roofline can be computed without
hardware.  Per template, per point of a 3-D stencil with halo h and block
(Bx, By, Bz):

  gmem/f4  — each tap's neighbor-block ref re-fetches blocks: unique
             fetched volume per output block for star stencils is the
             center block + 6 axis slabs → (Bx+2h)(By)(Bz) + ... but the
             Pallas pipeline fetches whole neighbor BLOCKS: worst-case
             distinct fetched bytes = (#deltas) · block.
  smem     — same fetched blocks, assembled once into a VMEM scratch.
  shift/unroll — 2.5D streaming: x is the whole local extent, so only
             y/z halos re-fetch: per-point factor ≈ ((By+2h)(Bz+2h))/(ByBz)
             for the streamed grid; coefficient grids stream exactly once.
  semi     — like shift, plus the rolling partial-sum buffer stays in VMEM.

Reported: modeled B/pt, VMEM working set (must fit ~128 MB), step time at
819 GB/s for the 1024³/256-chip local domain (64×64×1024), and roofline
fraction vs the 20 B/pt floor (4 reads + 1 write × f32).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import acoustic

HBM_BW = 819e9
VMEM_BYTES = 128 * 2 ** 20
LOCAL = (64, 64, 1024)            # 1024³ over the (16,16) mesh, axes 0,1
FLOOR_BPP = 20.0                  # 4 array reads + 1 write, f32


def _deltas_star_3d() -> int:
    return 7                       # center + 6 axis neighbors


def model(template: str, block: Tuple[int, int, int], h: int = 4,
          n_center_grids: int = 3) -> Dict:
    """B/pt + VMEM working set for the acoustic-ISO star stencil
    (1 halo'd grid p1 + n_center_grids center-only grids + 1 output)."""
    bx, by, bz = block
    pts = bx * by * bz
    if template in ("gmem", "smem", "f4"):
        # p1 fetches its block + 6 axis-neighbor blocks (star shape-
        # directed: no corners); center grids + output fetch 1 block each
        fetched = _deltas_star_3d() * pts + n_center_grids * pts + pts
        vmem = (_deltas_star_3d() + n_center_grids + 1) * pts * 4
        if template == "smem":
            vmem += (bx + 2 * h) * (by + 2 * h) * (bz + 2 * h) * 4
    elif template in ("shift", "unroll"):
        # stream x through the local extent: p1 re-fetches only y/z halos
        eff = (by + 2 * h) * (bz + 2 * h) / (by * bz)
        fetched = pts * (eff + n_center_grids + 1)
        # window of 2h+1 y/z planes + one in-flight block per grid
        vmem = (2 * h + 1) * (by + 2 * h) * (bz + 2 * h) * 4 \
            + (n_center_grids + 1) * by * bz * 4 * 2
    elif template == "semi":
        eff = (by + 2 * h) * (bz + 2 * h) / (by * bz)
        fetched = pts * (eff + n_center_grids + 1)
        vmem = (2 * h + 1) * (by + 2 * h) * (bz + 2 * h) * 4 * 2 \
            + (n_center_grids + 1) * by * bz * 4 * 2
    else:
        raise ValueError(template)
    bpp = 4.0 * fetched / pts
    local_pts = LOCAL[0] * LOCAL[1] * LOCAL[2]
    step_s = bpp * local_pts / HBM_BW
    return {"template": template, "block": block,
            "bytes_per_point": round(bpp, 1),
            "vmem_bytes": int(vmem),
            "vmem_ok": vmem <= VMEM_BYTES,
            "step_ms": round(step_s * 1e3, 3),
            "roofline_frac": round(FLOOR_BPP / bpp, 3)}


CANDIDATES = [
    ("gmem", (8, 8, 128)), ("gmem", (16, 16, 256)),
    ("smem", (8, 8, 128)), ("f4", (8, 8, 256)),
    ("shift", (64, 8, 128)), ("shift", (64, 16, 256)),
    ("shift", (64, 32, 512)),
    ("unroll", (64, 16, 256)),
    ("semi", (64, 16, 256)),
]


def run(verbose: bool = True) -> List[Dict]:
    k = acoustic.acoustic_iso_kernel
    assert k.info.shape == "star" and k.info.order == 4
    rows = []
    for template, block in CANDIDATES:
        r = model(template, block)
        rows.append(r)
        if verbose:
            print(f"{r['template']:7s} {str(r['block']):15s} "
                  f"{r['bytes_per_point']:7.1f} B/pt  "
                  f"VMEM {r['vmem_bytes'] / 2**20:6.1f} MB "
                  f"{'ok ' if r['vmem_ok'] else 'OVER'} "
                  f"step {r['step_ms']:7.3f} ms  "
                  f"roofline {r['roofline_frac'] * 100:5.1f}%", flush=True)
    best = max((r for r in rows if r["vmem_ok"]),
               key=lambda r: r["roofline_frac"])
    if verbose:
        print(f"\nbest: {best['template']} {best['block']} → "
              f"{best['bytes_per_point']} B/pt = "
              f"{best['roofline_frac'] * 100:.1f}% of the HBM roofline "
              f"({best['step_ms']} ms/step on the 64×64×1024 local domain)")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
