"""Benchmark regression guard for CI.

Compares a freshly produced ``BENCH_timeloop.json`` against the committed
baseline and fails (exit 1) when steps/s on a guarded series drops by more
than ``--threshold`` (default 20%, overridable via the
``BENCH_REGRESSION_THRESHOLD`` env var — CI runners are noisy, so the
guard is deliberately coarse; it exists to catch order-of-magnitude
schedule regressions, not single-digit jitter).

Guarded series: the fused steps/s of the committed star2d1r and
acoustic-ISO baselines.  Missing keys on either side are reported but do
not fail the guard (new benchmarks may add rows).

    python -m benchmarks.check_regression baseline.json fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GUARDED = (
    ("star2d1r", "fused_steps_per_s"),
    ("acoustic_iso_3d", "fused_steps_per_s"),
)


def check(baseline: dict, fresh: dict, threshold: float):
    """Return (failures, notes) comparing guarded steps/s series."""
    failures, notes = [], []
    for name, key in GUARDED:
        b = baseline.get(name, {}).get(key)
        f = fresh.get(name, {}).get(key)
        if b is None or f is None:
            notes.append(f"skip {name}.{key}: missing "
                         f"(baseline={b!r}, fresh={f!r})")
            continue
        ratio = f / b
        line = f"{name}.{key}: baseline {b:.1f} -> fresh {f:.1f} ({ratio:.2f}x)"
        if ratio < 1.0 - threshold:
            failures.append(line)
        else:
            notes.append(line)
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_timeloop.json")
    ap.add_argument("fresh", help="freshly measured BENCH_timeloop.json")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_THRESHOLD", "0.20")),
                    help="max allowed fractional steps/s drop (default 0.20)")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    failures, notes = check(baseline, fresh, args.threshold)
    for line in notes:
        print(f"  ok: {line}")
    for line in failures:
        print(f"REGRESSION (> {args.threshold:.0%} drop): {line}")
    if failures:
        return 1
    print("benchmark regression guard passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
