"""Benchmark regression guard for CI.

Compares a freshly produced ``BENCH_timeloop.json`` against the committed
baseline and fails (exit 1) when a guarded series drops by more than its
tolerance.

The committed baseline and the CI run come from *different machines*, so
absolute steps/s is not comparable — a slow runner would fail spuriously
and a fast one would mask real regressions.  The guard therefore only
checks machine-independent series:

  * same-run **speedup ratios** (fused vs per-step, measured back-to-back
    in one process on one machine — dimensionless, transfers across
    hardware up to scheduling noise, so the tolerance is coarse: the
    guard exists to catch order-of-magnitude schedule regressions, e.g.
    fusion silently degrading to the per-step path, not jitter), and
  * the plan's **modeled HBM-traffic reduction** for the temporally
    blocked pallas path (deterministic given the benchmark geometry, so
    its tolerance is tight).

Guarded series (dotted paths into the JSON) with their max allowed
fractional drop.  ``--threshold`` / the ``BENCH_REGRESSION_THRESHOLD``
env var override every tolerance at once when set.  Missing keys on
either side are reported but do not fail the guard (new benchmarks may
add or rename rows).

The guard set is selected by the benchmark kind, auto-detected from the
fresh JSON's top-level keys: ``BENCH_timeloop.json`` guards fusion /
temporal-blocking ratios, the same-run forward-vs-gradient ratio of the
differentiable timeloop (with its absolute √T-checkpoint and finite-
gradient booleans), plus the *absolute* cost-model-quality
invariants of the two-stage autotuner (the predicted ranking must place
the measured-best candidate in the top-K, the pruned search must stay
within 10% of the exhaustive winner, and it must measure at most K
candidates — booleans computed in-run, machine-independent);
``BENCH_serve.json`` guards the same-run batched-vs-serial serving
speedup plus the absolute invariants of the persistent autotune cache —
a warm cache must serve with **zero** measured candidates and a cold
one must measure at most its top-K shortlist (threshold overrides never
relax absolutes); ``BENCH_distributed.json`` guards the same-run
fused-vs-per-window speedup of the sharded timeloop and the same-run
forward-vs-gradient ratio of the distributed adjoint, the absolute
collective-model (forward and adjoint), mesh-tuning, and per-sub-mesh
adjoint-sanity booleans, and — a third category — the **exact**
deterministic series: ``HaloSpec``-modeled collective bytes (and the
transposed spec's adjoint bytes) depend only on geometry, so baseline
and fresh must agree to the byte (any drift means the exchange schedule
itself changed).

    python -m benchmarks.check_regression baseline.json fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GUARDED_TIMELOOP = (
    # (dotted path, max fractional drop)
    ("star2d1r.speedup", 0.50),
    ("acoustic_iso_3d.speedup", 0.50),
    ("star2d1r_pallas.time_block_4.hbm_reduction_vs_time_block_1", 0.10),
    ("star3d4r_pallas.time_block_4.hbm_reduction_vs_time_block_1", 0.10),
    # same-run forward/gradient ratio of the differentiable timeloop: the
    # checkpointed adjoint replays each window once and VJPs it once, so
    # this collapses if the backward pass degrades to O(T) residuals or
    # quadratic re-replay
    ("gradient_throughput.star2d1r.fwd_over_grad", 0.50),
)
GUARDED = GUARDED_TIMELOOP  # backwards-compat alias

GUARDED_SERVE = (
    # same-run ratio, machine-independent up to scheduling noise
    ("serve_stream.batched_vs_serial_speedup", 0.50),
)

#: (dotted path, required value) checked on the FRESH file only —
#: deterministic counters / in-run booleans, not timings, so equality
#: is exact
ABSOLUTE_SERVE = (
    ("autotune_cache.warm.measured_candidates", 0),
    ("autotune_cache.cold.measured_at_most_top_k", True),
)

#: cost-model quality: for every benchmarked kernel the predicted
#: ranking must place the measured-best candidate inside the top-K
#: shortlist, the pruned two-stage winner must be within 10% of the
#: exhaustive winner (same-run measurements), and the two-stage search
#: must measure no more than its shortlist
ABSOLUTE_TIMELOOP = tuple(
    (f"predicted_vs_measured.{kernel}.{flag}", True)
    for kernel in ("star2d1r", "star3d4r")
    for flag in ("best_in_top_k", "two_stage_within_10pct",
                 "measured_at_most_top_k")) + (
    # adjoint invariants, computed in-run: the checkpoint count stays
    # within the ⌈√T⌉ bound and the gradient is finite
    ("gradient_throughput.star2d1r.sqrt_checkpoint_bound", True),
    ("gradient_throughput.star2d1r.grad_finite", True),
)

GUARDED_DISTRIBUTED = (
    # one program per window vs one dispatch per exchange group,
    # measured back-to-back in the same subprocess
    ("fused_vs_per_window.speedup", 0.50),
    # same-run forward/gradient ratio of the DISTRIBUTED adjoint on 8
    # devices: collapses if the shard_mapped backward degrades to O(T)
    # residuals, quadratic re-replay, or a gathered wavefield
    ("gradient_scaling.throughput.8.fwd_over_grad", 0.50),
)

#: in-run booleans of the distributed benchmark: the HLO cross-checks of
#: the collective-traffic model (forward AND adjoint — the backward
#: program's collectives must equal the transposed spec's model) and the
#: mesh-aware two-stage tuner, plus the adjoint sanity invariants per
#: sub-mesh size
ABSOLUTE_DISTRIBUTED = tuple(
    (f"collective_model.{combo}.match", True)
    for combo in ("w4_d2", "w5_d2", "w6_d3")
) + tuple(
    (f"gradient_scaling.adjoint_collective_model.{combo}.match", True)
    for combo in ("w4_d2", "w5_d2", "w6_d3")
) + tuple(
    (f"gradient_scaling.throughput.{n}.{flag}", True)
    for n in (1, 2, 4, 8)
    for flag in ("grad_finite", "sqrt_checkpoint_bound")
) + (
    ("predicted_vs_measured_mesh.best_in_top_k", True),
    ("predicted_vs_measured_mesh.measured_at_most_top_k", True),
    ("predicted_vs_measured_mesh.distributed_pruning_active", True),
)

#: deterministic series compared EXACTLY between baseline and fresh —
#: the modeled collective bytes (forward and adjoint) are pure geometry
#: (no timing), so any difference is a real change to the exchange
#: schedule
EXACT_DISTRIBUTED = tuple(
    f"scaling.{mode}.{n}.modeled_collective_bytes_per_window"
    for mode in ("strong", "weak") for n in (1, 2, 4, 8)) + tuple(
    f"gradient_scaling.adjoint_collective_model.{combo}"
    f".modeled_adjoint_bytes"
    for combo in ("w4_d2", "w5_d2", "w6_d3"))


def _guards_for(fresh: dict):
    """(ratio, absolute, exact) guard sets for the benchmark kind of a
    file, auto-detected from its top-level keys."""
    if "serve_stream" in fresh:
        return GUARDED_SERVE, ABSOLUTE_SERVE, ()
    if "fused_vs_per_window" in fresh:
        return GUARDED_DISTRIBUTED, ABSOLUTE_DISTRIBUTED, EXACT_DISTRIBUTED
    return GUARDED_TIMELOOP, ABSOLUTE_TIMELOOP, ()


def _get(d: dict, path: str):
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict):
            return None
        cur = cur.get(part)
    return cur


def check(baseline: dict, fresh: dict, threshold: float = None):
    """Return (failures, notes) comparing guarded ratio series (and, for
    the serving benchmark, exact counter invariants on the fresh file).
    ``threshold`` overrides every per-series ratio tolerance when not
    None; absolute and exact checks are never relaxed."""
    failures, notes = [], []
    guarded, absolute, exact = _guards_for(fresh)
    for path, tol in guarded:
        if threshold is not None:
            tol = threshold
        b = _get(baseline, path)
        f = _get(fresh, path)
        if b is None or f is None:
            notes.append(f"skip {path}: missing "
                         f"(baseline={b!r}, fresh={f!r})")
            continue
        ratio = f / b
        line = (f"{path}: baseline {b:.2f}x -> fresh {f:.2f}x "
                f"({ratio:.2f}, tolerance {tol:.0%})")
        if ratio < 1.0 - tol:
            failures.append(line)
        else:
            notes.append(line)
    for path, want in absolute:
        f = _get(fresh, path)
        line = f"{path}: fresh {f!r} (required {want!r})"
        if f is None or f != want:
            failures.append(line)
        else:
            notes.append(line)
    for path in exact:
        b = _get(baseline, path)
        f = _get(fresh, path)
        if b is None or f is None:
            notes.append(f"skip {path}: missing "
                         f"(baseline={b!r}, fresh={f!r})")
            continue
        line = f"{path}: baseline {b!r} == fresh {f!r} (exact)"
        if b != f:
            failures.append(f"{path}: baseline {b!r} != fresh {f!r} "
                            f"(deterministic series must match exactly)")
        else:
            notes.append(line)
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    env = os.environ.get("BENCH_REGRESSION_THRESHOLD")
    ap.add_argument("baseline", help="committed BENCH_timeloop.json")
    ap.add_argument("fresh", help="freshly measured BENCH_timeloop.json")
    ap.add_argument("--threshold", type=float,
                    default=float(env) if env else None,
                    help="override the per-series tolerances (fractional "
                         "drop) with a single value")
    args = ap.parse_args(argv)

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    failures, notes = check(baseline, fresh, args.threshold)
    for line in notes:
        print(f"  ok: {line}")
    for line in failures:
        print(f"REGRESSION: {line}")
    if failures:
        return 1
    print("benchmark regression guard passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
