"""Paper §6.2 / Tables 6–8: per-template time-to-solution phase breakdown
for the acoustic-ISO 25-point stencil.

The paper reports frontend / codegen / compile / kernel / time-to-solution
per (template × block × mem-type) on H100/A100/MI210.  Our runtime is CPU
(TPU is a compile target), so kernel numbers are CPU-XLA / interpret-Pallas
wall times: they demonstrate the framework's low overhead (frontend+codegen
≪ compile ≪ kernel), not TPU performance — the TPU performance story is
the roofline analysis (benchmarks/roofline.py).

``xla`` rows play the role of the paper's hand-written reference; Pallas
rows run in interpret mode and are expected to be slow in wall-time but
identical in numerics (accuracy_suite.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import acoustic, dsl as st

CONFIGS = [
    # (template, block, mem_type)
    ("gmem", (8, 8, 128), None),
    ("gmem", (8, 16, 128), None),
    ("smem", (8, 8, 128), None),
    ("f4", (8, 8, 128), None),
    ("shift", (16, 8, 128), "registers"),
    ("shift", (16, 8, 128), "vmem"),
    ("unroll", (16, 8, 128), "registers"),
    ("semi", (16, 8, 128), "vmem"),
]


def run(shape=(32, 32, 128), iters=2, include_pallas=True,
        verbose=True) -> List[Dict]:
    rows = []

    def one(label, backend):
        # fresh kernel cache per variant so codegen/compile are measured
        acoustic.acoustic_iso_kernel._cache.clear()
        t0 = time.perf_counter()
        _, prof = acoustic.run(shape=shape, iters=iters, backend=backend)
        total = time.perf_counter() - t0
        row = {"template": label[0], "block": label[1], "mem": label[2] or "-",
               "frontend": acoustic.acoustic_iso_kernel.frontend_time,
               "codegen": prof.get("codegen", 0.0),
               "comp": prof.get("comp", 0.0),
               "kernel": prof.get("kernel", 0.0),
               "time_to_solution": total}
        rows.append(row)
        if verbose:
            print(f"{row['template']:7s} {str(row['block']):15s} "
                  f"{row['mem']:9s} fe={row['frontend']:.4f} "
                  f"cg={row['codegen']:.4f} comp={row['comp']:.3f} "
                  f"kern={row['kernel']:.3f} tts={row['time_to_solution']:.3f}",
                  flush=True)

    one(("xla", "-", None), st.xla())
    if include_pallas:
        for template, block, mem in CONFIGS:
            one((template, block, mem),
                st.pallas(template=template, block=block, mem_type=mem))
    return rows


def main():
    rows = run()
    fe = max(r["frontend"] for r in rows)
    cg = max(r["codegen"] for r in rows)
    print(f"\nframework overhead: frontend ≤ {fe * 1e3:.1f} ms, "
          f"codegen ≤ {cg * 1e3:.1f} ms per variant "
          f"(paper: ~4 ms / ~1-6 ms)")
    return rows


if __name__ == "__main__":
    main()
