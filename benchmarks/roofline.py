"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Three terms per (arch × shape), single-pod mesh, TPU v5e constants:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
    collective = collective_bytes_per_device / link_bw       (50e9 B/s ICI)

HLO_FLOPs / bytes / collective-bytes come from the trip-count-aware HLO
walk (launch/hlo_analysis.py) over ``compiled.as_text()`` — XLA's own
cost_analysis counts while bodies once and reports no collectives.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per device; the ratio
MODEL_FLOPS/HLO_FLOPs shows how much of the compiled compute is useful
(remat recompute, MoE capacity slack, replicated small-dim compute all
push it down).

``stencil_table()`` (also run by ``main``) is the stencil-suite analog:
per-kernel modeled ``hbm_bytes_per_step`` for the temporally blocked
pallas plan, read through ``core/cost_model.CostModel.step_bytes`` — the
*same* accounting the two-stage autotuner ranks candidates with — so
this report and the tuner's predictions can never drift apart.  The
``modeled_vs_roofline`` column compares each plan against the streaming
floor (one read per input grid + one write per output per point): >1
means temporal blocking beats per-step streaming; <1 means halo overlap
overhead still dominates at that geometry.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

DEFAULT_RECORDS = os.path.join(os.path.dirname(__file__), "artifacts",
                               "dryrun_baseline.json")


def model_flops(rec: Dict) -> Optional[float]:
    """6·N(_active)·D per device for the cell's step kind."""
    n = rec.get("active_params")
    if not n:
        return None
    B, S = rec["global_batch"], rec["seq_len"]
    ndev = rec["n_devices"]
    if rec["kind"] == "train":
        tokens = B * S
        mult = 6.0                        # fwd 2 + bwd 4
    elif rec["kind"] == "prefill":
        tokens = B * S
        mult = 2.0
    else:                                 # decode: one token per sequence
        tokens = B * 1
        mult = 2.0
    return mult * n * tokens / ndev


def terms(rec: Dict) -> Optional[Dict]:
    hw = rec.get("hlo_walk")
    if not hw:
        return None
    t_c = hw["total_flops"] / PEAK_FLOPS
    t_m = hw["hbm_bytes"] / HBM_BW
    t_x = hw["total_collective_bytes"] / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops(rec)
    bound = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[1],
        "step_lower_bound_s": bound,
        "model_flops_per_dev": mf,
        "useful_ratio": (mf / hw["total_flops"]) if mf and
        hw["total_flops"] else None,
        # roofline fraction: useful model FLOPs over the time the dominant
        # term pins the step to, vs peak
        "roofline_frac": (mf / bound / PEAK_FLOPS) if mf and bound else None,
    }


def load(path: str = DEFAULT_RECORDS) -> List[Dict]:
    """Load the merged baseline, or merge per-arch artifact JSONs."""
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    art = os.path.dirname(path)
    records = []
    for fn in sorted(os.listdir(art)) if os.path.isdir(art) else []:
        if fn.startswith("dryrun_") and fn.endswith(".json"):
            with open(os.path.join(art, fn)) as f:
                records.extend(json.load(f))
    return records


def table(records: List[Dict], mesh: str = "single",
          verbose: bool = True) -> List[Dict]:
    rows = []
    for rec in records:
        if rec.get("mesh") != mesh or rec.get("status") != "ok":
            continue
        t = terms(rec)
        if t is None:
            continue
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "tag": rec.get("tag", ""), **t}
        rows.append(row)
        if verbose:
            rf = f"{t['roofline_frac'] * 100:5.1f}%" \
                if t["roofline_frac"] else "   - "
            ur = f"{t['useful_ratio'] * 100:5.1f}%" \
                if t["useful_ratio"] else "   - "
            print(f"{rec['arch']:18s} {rec['shape']:12s} "
                  f"C={t['compute_s']:8.3f}s M={t['memory_s']:8.3f}s "
                  f"X={t['collective_s']:8.3f}s → {t['dominant']:10s} "
                  f"useful={ur} roofline={rf}", flush=True)
    return rows


def stencil_table(kernels=("star2d1r", "star2d4r", "star3d1r", "star3d4r"),
                  time_blocks=(1, 2, 4), verbose: bool = True) -> List[Dict]:
    """Modeled HBM traffic for the stencil suite at the suite's default
    benchmark shapes, via the cost model's ``step_bytes`` (identical to
    what the autotuner ranks with).  Deterministic — no timing, no
    compilation on the pallas path."""
    from repro.core import cost_model, dsl as st, suite

    cm = cost_model.CostModel(calibrate=False)
    rows = []
    for name in kernels:
        k = suite.get_kernel(name)
        swap = suite.swap_pair(name)
        grids = suite.make_grids(name)
        g0 = next(iter(grids.values()))
        interior = tuple(g0.shape)
        halos = {n: g.halo for n, g in grids.items()}
        itemsize = 4  # f32 suite grids
        points = 1.0
        for s in interior:
            points *= s
        # streaming floor: every read grid streamed once, every output
        # written once, zero halo overlap
        n_in = len(k.ir.input_grids())
        n_out = len(k.ir.output_grids())
        floor_bpp = itemsize * (n_in + n_out)
        for tb in time_blocks:
            backend = st.pallas(template="gmem", time_block=tb)
            sb = cm.step_bytes(k, halos, interior, backend, swap, g0.dtype)
            per_step = sb[0] if sb else float("inf")
            feasible = sb is not None and per_step != float("inf")
            bpp = per_step / points if feasible else None
            row = {
                "kernel": name, "shape": list(interior),
                "template": "gmem", "time_block": tb,
                "feasible": feasible,
                "hbm_bytes_per_step": per_step if feasible else None,
                "bytes_per_point": bpp,
                "streaming_floor_bytes_per_point": floor_bpp,
                "modeled_vs_roofline": (floor_bpp / bpp) if bpp else None,
                "hbm_step_s_at_819GBps": (per_step / HBM_BW
                                          if feasible else None),
            }
            rows.append(row)
            if verbose:
                if feasible:
                    print(f"{name:10s} k={tb}  "
                          f"hbm/step {per_step:12.0f} B  "
                          f"{bpp:6.1f} B/pt (floor {floor_bpp} B/pt, "
                          f"{row['modeled_vs_roofline']:.2f}x roofline)  "
                          f"t_mem {row['hbm_step_s_at_819GBps'] * 1e6:.1f}us",
                          flush=True)
                else:
                    print(f"{name:10s} k={tb}  infeasible at {interior}",
                          flush=True)
    return rows


def main():
    print("— stencil suite: modeled HBM traffic (cost-model accounting) —")
    stencil_table()
    print()
    records = load()
    if not records:
        print(f"no dry-run records under {os.path.dirname(DEFAULT_RECORDS)};"
              f" run\n  PYTHONPATH=src python -m repro.launch.dryrun --out "
              f"{DEFAULT_RECORDS}")
        return []
    rows = table(records)
    if rows:
        worst = min((r for r in rows if r["roofline_frac"]),
                    key=lambda r: r["roofline_frac"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"= {worst['roofline_frac'] * 100:.1f}%")
    return rows


if __name__ == "__main__":
    main()
