"""Fused vs per-step time-loop benchmark (the paper's time-to-solution
metric over many steps, §6.2 Tables 6–8 measured end-to-end).

For star2d1r and the acoustic-ISO 25-point stencil it runs N time steps

  * per-step: the classic ``@st.target`` Python loop — one compiled call,
    one host↔device sync and one dict repack per step, and
  * fused: ``st.timeloop`` — the whole loop traced once into a single
    ``lax.fori_loop`` program (one window),

and reports steps/s and time-to-solution.  The pallas rows (interpret
mode on CPU) sweep the in-kernel temporal-blocking depth ``time_block``
and report the plan's modeled ``hbm_bytes_per_step`` next to wall clock,
so the k× HBM-traffic reduction is visible even where interpret-mode
timing is noisy.  Results are written to ``BENCH_timeloop.json`` so the
perf trajectory is tracked across PRs (CI guards the machine-independent
speedup ratios and the modeled HBM reduction against the committed
baselines — see ``benchmarks/check_regression.py``).

    PYTHONPATH=src python -m benchmarks.timeloop [--fast]
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import acoustic, autotune, cost_model, dsl as st, suite
from repro.kernels.stencil import codegen

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_timeloop.json")


def _bench_star2d1r(steps: int, shape, repeats: int = 3) -> Dict:
    k = suite.get_kernel("star2d1r")
    swap = suite.swap_pair(k.name)

    def mk():
        return suite.make_grids("star2d1r", shape=shape)

    @st.target
    def per_step(u, v, iters):
        for _ in range(iters):
            st.map(e=u.shape)(k)(u, v)
            (u.data, v.data) = (v.data, u.data)

    def fused(u, v, iters):
        return st.timeloop(iters, swap=swap)(k)(u, v)

    run = st.launch(backend=st.xla())

    def time_once(tgt):
        g = mk()
        run(tgt)(*g.values(), 2)             # warmup: codegen + compile
        best = float("inf")
        for _ in range(repeats):
            g = mk()
            t0 = time.perf_counter()
            run(tgt)(*g.values(), steps)
            best = min(best, time.perf_counter() - t0)
        return best

    t_unfused = time_once(per_step)
    t_fused = time_once(fused)
    return {
        "kernel": "star2d1r", "backend": "xla", "shape": list(shape),
        "steps": steps,
        "unfused_seconds": t_unfused,
        "fused_seconds": t_fused,
        "unfused_steps_per_s": steps / t_unfused,
        "fused_steps_per_s": steps / t_fused,
        "speedup": t_unfused / t_fused,
    }


def _bench_pallas_sweep(name: str, steps: int, shape, repeats: int = 5,
                        time_blocks=(1, 2, 4)) -> Dict:
    """Fused pallas path (interpret on CPU) across temporal depths: wall
    clock plus the plan's modeled HBM bytes per step — the k× traffic
    reduction is the column that carries to real TPUs.  Used for the 5-pt
    star2d1r and the paper's headline 25-point star3d4r (whose order-4
    halo needs a domain that admits the k·h=16 expanded window at k=4)."""
    k = suite.get_kernel(name)
    swap = suite.swap_pair(k.name)
    halos = {g: k.info.halo for g in k.ir.grid_params}
    if shape is None:  # the suite's per-order default
        shape = next(iter(suite.make_grids(name).values())).shape
    rows = {}
    for tb in time_blocks:
        backend = st.pallas(template="gmem", time_block=tb)
        plan = codegen.plan_pallas(k.ir, halos, tuple(shape), backend,
                                   swap=swap)

        def fused(*args):
            return st.timeloop(steps, swap=swap)(k)(*args)

        run = st.launch(backend=backend)
        g = suite.make_grids(name, shape=shape)
        run(fused)(*g.values())          # warmup compiles the real window
        best = float("inf")
        for _ in range(repeats):
            g = suite.make_grids(name, shape=shape)
            t0 = time.perf_counter()
            run(fused)(*g.values())
            best = min(best, time.perf_counter() - t0)
        rows[f"time_block_{tb}"] = {
            "kernel": name, "backend": "pallas_interpret",
            "template": "gmem", "time_block": tb, "shape": list(shape),
            "steps": steps,
            "fused_seconds": best,
            "fused_steps_per_s": steps / best,
            "hbm_bytes_per_step": plan.hbm_bytes_per_step(),
            "grid_reads_per_step": plan.grid_reads_per_step,
            "grid_writes_per_step": plan.grid_writes_per_step,
        }
    base = rows.get("time_block_1")
    if base:
        for r in rows.values():
            r["speedup_vs_time_block_1"] = (base["fused_seconds"]
                                            / r["fused_seconds"])
            r["hbm_reduction_vs_time_block_1"] = (
                base["hbm_bytes_per_step"] / r["hbm_bytes_per_step"])
    return rows


def _bench_acoustic(steps: int, shape, repeats: int = 2) -> Dict:
    def time_once(fuse):
        acoustic.run(shape=shape, iters=2, with_source=False,
                     fuse_steps=fuse)   # warmup
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            acoustic.run(shape=shape, iters=steps, with_source=False,
                         fuse_steps=fuse)
            best = min(best, time.perf_counter() - t0)
        return best

    t_unfused = time_once(None)
    t_fused = time_once(steps)
    return {
        "kernel": "acoustic_iso_3d", "backend": "xla", "shape": list(shape),
        "steps": steps,
        "unfused_seconds": t_unfused,
        "fused_seconds": t_fused,
        "unfused_steps_per_s": steps / t_unfused,
        "fused_steps_per_s": steps / t_fused,
        "speedup": t_unfused / t_fused,
    }


def _bench_gradient_throughput(name: str, shape, steps: int,
                               repeats: int = 3) -> Dict:
    """Adjoint cost of the differentiable timeloop: jitted forward vs
    jitted loss+gradient wall clock on the same window schedule, plus the
    schedule's checkpoint count against the ⌈√T⌉ bound.  The
    machine-independent columns CI guards are ``fwd_over_grad`` (the
    checkpointed backward replays each window once and runs its VJP once,
    so grad should stay within a small constant factor of forward — it
    collapses if the adjoint degrades to O(T) residuals or re-replays
    segments) and the ``sqrt_checkpoint_bound`` / ``grad_finite``
    booleans."""
    import jax
    import jax.numpy as jnp
    from repro.core import adjoint, timeloop as tl

    k = suite.get_kernel(name)
    grids = suite.make_grids(name, shape=shape)
    eng = tl.TimeloopEngine(k.ir, {n: g.halo for n, g in grids.items()},
                            tuple(shape), st.xla(),
                            swap=suite.swap_pair(name), differentiable=True)
    fn = adjoint.differentiable_run(eng, steps)
    arrays = {n: g.data for n, g in grids.items()}

    fwd = jax.jit(lambda a: fn(a, {}))
    grad = jax.jit(jax.grad(lambda a: sum(jnp.sum(o ** 2)
                                          for o in fn(a, {}).values())))

    def time_once(f):
        jax.block_until_ready(f(arrays))     # compile + warm
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(f(arrays))
            best = min(best, time.perf_counter() - t0)
        return best

    t_fwd = time_once(fwd)
    t_grad = time_once(grad)
    g = grad(arrays)
    finite = all(bool(np.isfinite(np.asarray(v)).all()) for v in g.values())
    bound = adjoint.ceil_sqrt(steps) + 1
    return {
        "kernel": name, "backend": "xla", "shape": list(shape),
        "steps": steps,
        "fwd_seconds": t_fwd,
        "grad_seconds": t_grad,
        "fwd_steps_per_s": steps / t_fwd,
        "grad_steps_per_s": steps / t_grad,
        "fwd_over_grad": t_fwd / t_grad,
        "checkpoints": fn.schedule["checkpoints"],
        "windows": len(fn.schedule["windows"]),
        "sqrt_checkpoint_bound": bool(fn.schedule["checkpoints"] <= bound),
        "grad_finite": finite,
    }


def _bench_predicted_vs_measured(name: str, shape, steps: int,
                                 space, fuse_space, time_block_space,
                                 top_k: int = 3) -> Dict:
    """Cost-model quality on a real search space: run the exhaustive
    search (top_k=None) and the two-stage pruned search over the same
    candidates, then compare the two winners with the *exhaustive* run's
    measurements (same-run numbers, so the ratio is not same-candidate
    noise).  ``best_in_top_k`` / ``two_stage_within_10pct`` /
    ``measured_at_most_top_k`` are the machine-independent booleans CI
    guards."""
    k = suite.get_kernel(name)
    swap = suite.swap_pair(name)
    model = cost_model.default_model()

    def grids():
        return suite.make_grids(name, shape=shape)

    def search(top):
        autotune.clear_cache()
        autotune.reset_measure_count()
        res = autotune.tune(k, grids(), iters=1, space=space, swap=swap,
                            steps=steps, fuse_space=fuse_space,
                            time_block_space=time_block_space,
                            top_k=top, cost_model=model)
        return res, dict(autotune.MEASURE_COUNT)

    exhaustive, _ = search(None)
    two_stage, counts = search(top_k)

    def trial_key(backend, fuse):
        return (backend.cache_key(), fuse)

    ex_by_key = {trial_key(b, f): dt for b, f, dt in exhaustive.trials}
    ts_in_ex = ex_by_key.get(trial_key(two_stage.backend,
                                       two_stage.fuse_steps))
    ratio = (ts_in_ex / exhaustive.seconds
             if ts_in_ex is not None and exhaustive.seconds > 0 else None)
    n_cands = len(exhaustive.trials)
    rank = exhaustive.rank_error
    return {
        "kernel": name, "shape": list(shape), "steps": steps,
        "candidates": n_cands,
        "top_k": top_k,
        "exhaustive_best_seconds": exhaustive.seconds,
        "exhaustive_best_backend": str(exhaustive.backend),
        "exhaustive_best_fuse": exhaustive.fuse_steps,
        "two_stage_best_seconds": two_stage.seconds,
        "two_stage_best_backend": str(two_stage.backend),
        "two_stage_best_fuse": two_stage.fuse_steps,
        "two_stage_best_seconds_in_exhaustive": ts_in_ex,
        "two_stage_vs_exhaustive": ratio,
        "two_stage_within_10pct": bool(ratio is not None and ratio <= 1.10),
        "rank_of_measured_best": rank,
        "best_in_top_k": bool(rank is not None and rank < top_k),
        "measured_candidates_two_stage": counts["measured_candidates"],
        "pruned_candidates": counts["pruned_candidates"],
        "measured_at_most_top_k": bool(
            counts["measured_candidates"] <= top_k
            + sum(1 for _, _, p in two_stage.predicted if p is None)),
    }


def run(fast: bool = False, verbose: bool = True) -> Dict[str, Dict]:
    steps = 30 if fast else 100
    results = {
        "star2d1r": _bench_star2d1r(steps, (128, 128) if fast else (256, 256)),
        "acoustic_iso_3d": _bench_acoustic(
            steps, (24, 24, 24) if fast else (48, 48, 48)),
        "star2d1r_pallas": _bench_pallas_sweep(
            "star2d1r", 10 if fast else 24,
            (64, 64) if fast else (128, 128)),
        # the paper's headline 25-point star: suite default (32, 32, 64)
        # admits the full time_block ∈ {1, 2, 4} sweep (k·h = 16 ≤ block)
        "star3d4r_pallas": _bench_pallas_sweep(
            "star3d4r", 4 if fast else 8, None, repeats=1 if fast else 2),
        # adjoint throughput: forward vs checkpointed gradient (CI guards
        # fwd_over_grad and the √T-checkpoint / finite-grad booleans)
        "gradient_throughput": {
            "star2d1r": _bench_gradient_throughput(
                "star2d1r", (64, 64) if fast else (128, 128),
                16 if fast else 64),
        },
        # two-stage autotuner quality: exhaustive vs cost-model-pruned
        # search over mixed xla/pallas spaces (CI guards the booleans)
        "predicted_vs_measured": {
            "star2d1r": _bench_predicted_vs_measured(
                "star2d1r", (48, 48), 8,
                space=[st.xla(), st.pallas(template="gmem"),
                       st.pallas(template="smem")],
                fuse_space=(1, 8), time_block_space=(1, 2)),
            "star3d4r": _bench_predicted_vs_measured(
                "star3d4r", (16, 16, 32), 4,
                space=[st.xla(), st.pallas(template="gmem")],
                fuse_space=(1, 4), time_block_space=(1, 2)),
        },
    }
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    if verbose:
        for name, r in results.items():
            if name == "predicted_vs_measured":
                for key, row in sorted(r.items()):
                    print(f"{name:16s} {key:13s} "
                          f"measured {row['measured_candidates_two_stage']}"
                          f"/{row['candidates']} cands  "
                          f"rank-of-best {row['rank_of_measured_best']}  "
                          f"vs exhaustive "
                          f"{row['two_stage_vs_exhaustive']:.3f}x",
                          flush=True)
            elif name == "gradient_throughput":
                for key, row in sorted(r.items()):
                    print(f"{name:16s} {key:13s} "
                          f"fwd {row['fwd_steps_per_s']:8.1f} steps/s  "
                          f"grad {row['grad_steps_per_s']:8.1f} steps/s  "
                          f"({row['fwd_over_grad']:.2f}x, "
                          f"{row['checkpoints']}/{row['windows']} ckpts)",
                          flush=True)
            elif "unfused_steps_per_s" in r:
                print(f"{name:16s} {r['steps']:4d} steps  "
                      f"per-step {r['unfused_steps_per_s']:8.1f} steps/s  "
                      f"fused {r['fused_steps_per_s']:8.1f} steps/s  "
                      f"speedup {r['speedup']:.2f}x", flush=True)
            else:
                for key, row in sorted(r.items()):
                    print(f"{name:16s} {key:13s} "
                          f"{row['fused_steps_per_s']:8.1f} steps/s  "
                          f"hbm/step {row['hbm_bytes_per_step']:10.0f} B  "
                          f"({row.get('speedup_vs_time_block_1', 1.0):.2f}x "
                          "vs k=1)", flush=True)
        print(f"wrote {OUT_PATH}")
    return results


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    return run(fast=args.fast)


if __name__ == "__main__":
    main()
