"""Paper §6.3 / Table 11: developer-productivity metrics — lines of code
for the same stencil expressed in the DSL vs what the framework generates
and vs a hand-written backend-level implementation.

The paper compares 285 LoC of StencilPy against 1034–1480 LoC of
hand-crafted CUDA/HIP/SYCL/STX.  Our backend-level artifact is the
generated HLO (per template); we report DSL source LoC, HLO line counts,
and the LoC of the hand-rolled jnp reference implementation shipped in
this repo.
"""
from __future__ import annotations

import inspect
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acoustic, dsl as st, suite
from repro.kernels.stencil import codegen

TEMPLATES = ("gmem", "smem", "f4", "shift", "unroll", "semi")


def _loc(src: str) -> int:
    return sum(1 for l in src.splitlines()
               if l.strip() and not l.strip().startswith("#"))


def dsl_loc(kernel) -> int:
    src = getattr(kernel.fn, "__stencil_source__", None)
    if src is None:
        src = inspect.getsource(kernel.fn)
    return _loc(src)


def generated_hlo_lines(kernel, template: str, interior) -> int:
    halos = {g: kernel.info.halo for g in kernel.ir.grid_params}
    backend = st.pallas(template=template)
    fn = codegen.lower_pallas(kernel.ir, halos, interior, None, backend)
    arrays = {g: jax.ShapeDtypeStruct(
        tuple(s + 2 * h for s, h in zip(interior, halos[g])), jnp.float32)
        for g in kernel.ir.grid_params}
    scalars = {n: jax.ShapeDtypeStruct((), jnp.float32)
               for n, _ in kernel.ir.scalar_params}
    lowered = jax.jit(fn).lower(arrays, scalars)
    return len(lowered.as_text().splitlines())


def run(verbose=True) -> List[Dict]:
    rows = []
    cases = [("acoustic_iso", acoustic.acoustic_iso_kernel, (16, 16, 128)),
             ("star2d4r", suite.get_kernel("star2d4r"), (32, 128)),
             ("box3d2r", suite.get_kernel("box3d2r"), (16, 16, 128))]
    for name, k, interior in cases:
        d = dsl_loc(k)
        for t in TEMPLATES:
            g = generated_hlo_lines(k, t, interior)
            rows.append({"kernel": name, "template": t, "dsl_loc": d,
                         "generated_lines": g,
                         "leverage": round(g / max(d, 1), 1)})
            if verbose:
                r = rows[-1]
                print(f"{name:14s} {t:7s} DSL={d:3d} LoC → "
                      f"{g:5d} generated lines ({r['leverage']}×)",
                      flush=True)
    # framework-level comparison (paper Table 11's '285 vs 1034-1480')
    import repro.core.lowering as lowering_mod
    import repro.kernels.stencil.codegen as codegen_mod
    hand = _loc(inspect.getsource(lowering_mod)) \
        + _loc(inspect.getsource(codegen_mod))
    if verbose:
        print(f"\nbackend implementation (shared by ALL kernels): "
              f"{hand} LoC — amortized once, vs per-kernel hand-porting")
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
