"""§Perf hillclimb driver: hypothesis → change → re-lower → re-analyse.

Each experiment is one dry-run cell with an override set; results append to
``benchmarks/artifacts/perf_experiments.json``.  EXPERIMENTS.md §Perf
narrates the hypotheses and verdicts; this file is the executable record.

Run (needs the 512-device env, so it self-launches):
    PYTHONPATH=src python -m benchmarks.perf_experiments [--only PREFIX]
"""
import os
import subprocess
import sys

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts")
_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

# (name, kind, args) — kind: 'lm' → run_cell, 'stencil' → run_stencil_cell
EXPERIMENTS = [
    # -- pair 1: mixtral-8x7b train_4k (worst useful-ratio big cell) ------
    ("mixtral_train_mb4", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4")),
    ("mixtral_train_mb2", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 2}, tag="-mb2")),
    ("mixtral_train_dots", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"remat_policy": "dots"}, tag="-dots")),
    ("mixtral_train_mb4_dots", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4, "remat_policy": "dots"},
          tag="-mb4-dots")),
    ("mixtral_train_group8k", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4-g8k",
          moe_group=8192)),

    # -- pair 2: granite-8b decode_32k (most collective-bound) ------------
    ("granite_decode_kvrep", "lm",
     dict(arch="granite-8b", shape="decode_32k", multi_pod=False,
          overrides={}, tag="-kvrep", kv_seq_shard=False)),

    # -- pair 3: recurrentgemma train_4k (paper-technique representative) -
    ("rgemma_train_mb4", "lm",
     dict(arch="recurrentgemma-9b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4")),
    ("rgemma_train_mb4_dots", "lm",
     dict(arch="recurrentgemma-9b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4, "remat_policy": "dots"},
          tag="-mb4-dots")),

    # -- the paper's own workload: overlapped tiling -----------------------
    ("acoustic_ts2", "stencil",
     dict(multi_pod=False, time_steps=2, tag="-ts2")),
    ("acoustic_ts4", "stencil",
     dict(multi_pod=False, time_steps=4, tag="-ts4")),
    ("acoustic_ts2_multi", "stencil",
     dict(multi_pod=True, time_steps=2, tag="-ts2")),

    # -- pair 2, iteration 2: seq-mode-aware decode attention landed in
    #    layers._sdpa (kv_mode) + cache DUS constraints -------------------
    ("granite_decode_seqflash", "lm",
     dict(arch="granite-8b", shape="decode_32k", multi_pod=False,
          overrides={}, tag="-seqflash")),
    ("mixtral_decode_seqflash", "lm",
     dict(arch="mixtral-8x7b", shape="decode_32k", multi_pod=False,
          overrides={}, tag="-seqflash")),

    # -- pair 2, iteration 3: grouped-query decode attention (no expanded
    #    KV materialization) --------------------------------------------
    ("granite_decode_grouped", "lm",
     dict(arch="granite-8b", shape="decode_32k", multi_pod=False,
          overrides={}, tag="-grouped")),
    ("mixtral_decode_grouped", "lm",
     dict(arch="mixtral-8x7b", shape="decode_32k", multi_pod=False,
          overrides={}, tag="-grouped")),
    ("mixtral_long500k_grouped", "lm",
     dict(arch="mixtral-8x7b", shape="long_500k", multi_pod=False,
          overrides={}, tag="-grouped")),

    # -- pair 1, iteration 2: grad accumulator pinned to param sharding
    #    (reduce-scatter instead of replicated all-reduce) ----------------
    ("mixtral_train_mb4_gshard", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4-gshard")),
    ("mixtral_train_mb8_gshard", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 8}, tag="-mb8-gshard")),
    ("rgemma_train_mb4_gshard", "lm",
     dict(arch="recurrentgemma-9b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4-gshard")),

    # -- pair 1, iteration 3: bf16 x-path norms keep the TP backward
    #    all-reduce in bf16 (f32 convert no longer hoisted before it) ----
    ("mixtral_train_mb4_bf16ar", "lm",
     dict(arch="mixtral-8x7b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4-bf16ar")),
    ("rgemma_train_mb4_bf16ar", "lm",
     dict(arch="recurrentgemma-9b", shape="train_4k", multi_pod=False,
          overrides={"n_microbatches": 4}, tag="-mb4-bf16ar")),
]

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
import dataclasses
from repro.launch import dryrun
from repro import sharding
spec = json.loads(sys.argv[1])
kind = spec.pop("kind")
name = spec.pop("name")
if kind == "stencil":
    rec = dryrun.run_stencil_cell(spec["multi_pod"],
                                  time_steps=spec.get("time_steps", 1),
                                  overlap=spec.get("overlap", True),
                                  tag=spec.get("tag", ""), save_hlo=True)
else:
    if not spec.pop("kv_seq_shard", True):
        # experiment: replicate KV-cache seq dim instead of model-sharding
        orig = sharding._kv_cache_axes
        def no_seq(cfg, mesh, lead):
            return lead + ("batch", None, "kv_heads", "head_dim")
        sharding._kv_cache_axes = no_seq
    mg = spec.pop("moe_group", None)
    overrides = spec.pop("overrides", {})
    if mg:
        from repro import configs
        cfg = configs.get(spec["arch"])
        overrides["moe"] = dataclasses.replace(cfg.moe, group_size=mg)
    rec = dryrun.run_cell(spec["arch"], spec["shape"], spec["multi_pod"],
                          save_hlo=True, overrides=overrides,
                          tag=spec.get("tag", ""))
rec["experiment"] = name
print("RESULT " + json.dumps(rec))
"""


def run_experiment(name, kind, args):
    import json
    spec = dict(args, kind=kind, name=name)
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _CHILD, json.dumps(spec)],
                       capture_output=True, text=True, env=env, timeout=3000)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[7:])
    raise RuntimeError(f"{name} failed:\n{r.stdout[-2000:]}\n"
                       f"{r.stderr[-2000:]}")


def main(argv=None):
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    out_path = os.path.join(ART, "perf_experiments.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {r.get("experiment") for r in results}
    for name, kind, spec in EXPERIMENTS:
        if args.only and not name.startswith(args.only):
            continue
        if name in done:
            print(f"[cached ] {name}")
            continue
        try:
            rec = run_experiment(name, kind, spec)
        except Exception as e:
            print(f"[FAILED ] {name}: {e}")
            continue
        results.append(rec)
        hw = rec.get("hlo_walk") or {}
        mem = (rec.get("memory") or {}).get("per_device_total_bytes", 0)
        print(f"[ok     ] {name:28s} mem={mem / 2**30:6.1f}GB "
              f"flops={hw.get('total_flops', 0):.3e} "
              f"hbm={hw.get('hbm_bytes', 0):.3e} "
              f"coll={hw.get('total_collective_bytes', 0):.3e}", flush=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
