"""Paper §6.1 / Table 4: numerical accuracy of generated code.

Runs the 20-kernel suite (star/box × 2D/3D × order 1–4 + Jacobi) through
every backend/template/mem-type variant and reports max-error + RMSD
against the reference lowering (the paper's OpenMP-reference analogue).
The paper's acceptance bar: max err ~1e-7, RMSD ~1e-8 (f32).
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import dsl as st, suite
from repro.kernels.stencil import ops, ref

SHAPE_2D = (40, 56)
SHAPE_3D = (16, 24, 40)
TEMPLATES = ("gmem", "smem", "f4", "shift", "unroll", "semi")


def _arrays(kernel, interior, seed=0):
    rng = np.random.default_rng(seed)
    halos = {g: kernel.info.halo for g in kernel.ir.grid_params}
    arrays = {}
    for g in kernel.ir.grid_params:
        full = tuple(s + 2 * h for s, h in zip(interior, halos[g]))
        arrays[g] = jnp.asarray(rng.standard_normal(full), jnp.float32)
    return arrays, halos


def variants_for(kernel):
    """Code-version pool per kernel (backend × template × mem-type ×
    block), mirroring the paper's 1383-version sweep at reduced size."""
    out = [("xla", None, None)]
    for t in TEMPLATES:
        if t in ("shift", "unroll", "semi"):
            for m in ("registers", "vmem"):
                out.append(("pallas", t, m))
        else:
            out.append(("pallas", t, None))
    return out


def run(kernels=None, verbose=True) -> List[Dict]:
    rows = []
    names = kernels or suite.KERNEL_NAMES
    for name in names:
        k = suite.get_kernel(name)
        interior = SHAPE_2D if k.info.ndim == 2 else SHAPE_3D
        arrays, halos = _arrays(k, interior)
        want = ref.reference_apply(k.ir, halos, interior, dict(arrays))
        for backend, template, mem in variants_for(k):
            t0 = time.perf_counter()
            if backend == "xla":
                got = want
            else:
                got = ops.stencil_apply(k, dict(arrays), halos=halos,
                                        template=template, mem_type=mem)
            dt = time.perf_counter() - t0
            errs = []
            for g in k.ir.output_grids():
                e = np.abs(np.asarray(got[g], np.float64)
                           - np.asarray(want[g], np.float64))
                errs.append(e)
            e = np.concatenate([x.ravel() for x in errs])
            rows.append({
                "kernel": name, "backend": backend,
                "template": template or "-", "mem": mem or "-",
                "ndim": k.info.ndim, "shape": k.info.shape,
                "order": k.info.order,
                "flops_per_point": k.info.flops_per_point,
                "max_err": float(e.max()),
                "rmsd": float(np.sqrt((e ** 2).mean())),
                "seconds": dt,
            })
            if verbose:
                r = rows[-1]
                print(f"{name:12s} {backend:6s} {r['template']:7s} "
                      f"{r['mem']:9s} max={r['max_err']:.2e} "
                      f"rmsd={r['rmsd']:.2e}", flush=True)
    return rows


def main():
    rows = run()
    worst = max(rows, key=lambda r: r["max_err"])
    n_versions = len(rows)
    print(f"\n{n_versions} code versions validated; "
          f"worst max_err={worst['max_err']:.2e} "
          f"({worst['kernel']}/{worst['template']}), "
          f"all rmsd ≤ {max(r['rmsd'] for r in rows):.2e}")
    assert worst["max_err"] < 1e-4, "accuracy regression"
    return rows


if __name__ == "__main__":
    main()
