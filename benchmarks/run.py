"""Benchmark aggregator: one module per paper table + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Emits a section per table; each section also prints ``name,value`` CSV
lines for machine consumption.  The dry-run/roofline section reads the
baseline artifact JSON if present (produced by repro.launch.dryrun — a
separate process because it needs 512 placeholder devices).
"""
from __future__ import annotations

import argparse
import sys
import time


def _hdr(title):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sweeps (CI mode)")
    args = ap.parse_args(argv)

    t0 = time.time()

    _hdr("Accuracy suite (paper Table 4 / §6.1)")
    from benchmarks import accuracy_suite
    kernels = ("star2d2r", "star3d4r", "box2d1r", "box3d2r", "j2d5pt",
               "j3d27pt") if args.fast else None
    acc = accuracy_suite.run(kernels=kernels)
    print(f"csv,accuracy_versions,{len(acc)}")
    print(f"csv,accuracy_worst_max_err,{max(r['max_err'] for r in acc):.3e}")
    print(f"csv,accuracy_worst_rmsd,{max(r['rmsd'] for r in acc):.3e}")

    _hdr("Template timing (paper Tables 6-8 / §6.2)")
    from benchmarks import template_timing
    tt = template_timing.run(
        shape=(16, 16, 128) if args.fast else (32, 32, 128),
        iters=1 if args.fast else 2,
        include_pallas=not args.fast)
    for r in tt:
        print(f"csv,tts_{r['template']}_{r['mem']},"
              f"{r['time_to_solution']:.3f}")

    _hdr("Fused time loop (steps/s, fused vs per-step; BENCH_timeloop.json)")
    from benchmarks import timeloop as bench_timeloop
    tl = bench_timeloop.run(fast=args.fast)
    for name, r in tl.items():
        if name == "predicted_vs_measured":
            # two-stage autotuner quality: nested per-kernel rows
            for key, row in sorted(r.items()):
                print(f"csv,timeloop_pvm_{key}_measured,"
                      f"{row['measured_candidates_two_stage']}")
                print(f"csv,timeloop_pvm_{key}_pruned,"
                      f"{row['pruned_candidates']}")
                print(f"csv,timeloop_pvm_{key}_rank_of_best,"
                      f"{row['rank_of_measured_best']}")
        elif "fused_steps_per_s" in r:
            print(f"csv,timeloop_{name}_steps_per_s,"
                  f"{r['fused_steps_per_s']:.1f}")
            print(f"csv,timeloop_{name}_speedup,{r['speedup']:.2f}")
        else:   # pallas time_block sweep: nested rows
            for key, row in sorted(r.items()):
                print(f"csv,timeloop_{name}_{key}_steps_per_s,"
                      f"{row['fused_steps_per_s']:.1f}")
                print(f"csv,timeloop_{name}_{key}_hbm_bytes_per_step,"
                      f"{row['hbm_bytes_per_step']:.0f}")

    _hdr("Productivity (paper Table 11 / §6.3)")
    from benchmarks import productivity
    pr = productivity.run()
    print(f"csv,productivity_min_leverage,"
          f"{min(r['leverage'] for r in pr)}")

    _hdr("Distributed stencil (fused sharded timeloop; BENCH_distributed.json)")
    from benchmarks import distributed_stencil
    ds = distributed_stencil.run(fast=args.fast)
    fw = ds["fused_vs_per_window"]
    print(f"csv,dist_fused_vs_per_window_speedup,{fw['speedup']:.2f}")
    print(f"csv,dist_fused_steps_per_s,{fw['fused_steps_per_s']:.1f}")
    for mode in ("strong", "weak"):
        for n, row in sorted(ds["scaling"][mode].items(),
                             key=lambda kv: int(kv[0])):
            print(f"csv,dist_{mode}_{n}dev_steps_per_s,"
                  f"{row['steps_per_s']:.1f}")
    print(f"csv,dist_collective_model_match,"
          f"{int(all(r['match'] for r in ds['collective_model'].values()))}")
    pvm = ds["predicted_vs_measured_mesh"]
    print(f"csv,dist_pvm_measured,{pvm['measured_candidates']}")
    print(f"csv,dist_pvm_pruned,{pvm['pruned_candidates']}")

    _hdr("Stencil-template roofline (BlockSpec traffic model, §Perf)")
    from benchmarks import stencil_roofline
    sr = stencil_roofline.run()
    best = max((r for r in sr if r["vmem_ok"]),
               key=lambda r: r["roofline_frac"])
    print(f"csv,stencil_best_bpp,{best['bytes_per_point']}")
    print(f"csv,stencil_best_roofline_frac,{best['roofline_frac']}")

    _hdr("Roofline (from dry-run artifacts; see EXPERIMENTS.md §Roofline)")
    from benchmarks import roofline
    rl = roofline.main()
    if rl:
        fracs = [r["roofline_frac"] for r in rl if r["roofline_frac"]]
        if fracs:
            print(f"csv,roofline_cells,{len(rl)}")
            print(f"csv,roofline_best_frac,{max(fracs):.3f}")
            print(f"csv,roofline_worst_frac,{min(fracs):.3f}")

    print(f"\nall benchmarks done in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
