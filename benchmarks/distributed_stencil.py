"""Beyond-paper benchmark: the distributed stencil runtime (shard_map
domain decomposition + ppermute halo exchange) on 8 simulated host devices.

Runs in a subprocess (the main process must keep 1 device per the dry-run
contract).  Validates bitwise-vs-single-device numerics and reports wall
time with/without interior/boundary overlap decomposition.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")

_CODE = """
import time
import jax, numpy as np, jax.numpy as jnp
from repro.core import acoustic, dsl as st

mesh = jax.make_mesh({mesh_shape}, {axis_names})
t0 = time.perf_counter()
backend = st.distributed(grid_axes={grid_axes}, overlap={overlap})
p, prof = acoustic.run(shape={shape}, iters={iters}, backend=backend,
                       mesh=mesh)
wall = time.perf_counter() - t0
ref, _ = acoustic.run(shape={shape}, iters={iters}, backend=st.xla())
err = float(jnp.max(jnp.abs(p.interior - ref.interior)))
assert err < 1e-4, err
print(f"RESULT {{wall:.3f}} {{err:.2e}}")
"""


def run(fast: bool = False, verbose: bool = True) -> List[Dict]:
    shape = (32, 32, 64) if fast else (64, 64, 64)
    iters = 2 if fast else 4
    cases = [
        ("1d_overlap", (8,), ("data",), ("data", None, None), True),
        ("1d_no_overlap", (8,), ("data",), ("data", None, None), False),
        ("2d_overlap", (4, 2), ("data", "model"),
         ("data", "model", None), True),
        ("3d_pod", (2, 2, 2), ("pod", "data", "model"),
         ("pod", "data", "model"), True),
    ]
    rows = []
    for name, mesh_shape, axis_names, grid_axes, overlap in cases:
        code = _CODE.format(mesh_shape=mesh_shape, axis_names=axis_names,
                            grid_axes=grid_axes, overlap=overlap,
                            shape=shape, iters=iters)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = _SRC
        t0 = time.perf_counter()
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        assert r.returncode == 0, f"{name}:\n{r.stdout}\n{r.stderr}"
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
        wall, err = line.split()[1:]
        rows.append({"name": name, "seconds": float(wall),
                     "max_err_vs_single": float(err)})
        if verbose:
            print(f"{name:16s} wall={wall}s err={err} "
                  f"(subprocess total {time.perf_counter() - t0:.1f}s)",
                  flush=True)
    return rows


def main():
    return run()


if __name__ == "__main__":
    main()
