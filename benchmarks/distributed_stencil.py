"""Distributed stencil benchmark: the fused sharded timeloop on 8
simulated host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
the subprocess exists because the main process must keep 1 device per the
dry-run contract).  Emits ``BENCH_distributed.json`` with five sections:

* ``fused_vs_per_window`` — the tentpole ratio: W steps as ONE
  shard_mapped program (fori_loop over exchange groups) vs the same
  steps as one dispatched program *per exchange group* (the old
  per-window path).  Same depth, same numerics, same run — the speedup
  is dimensionless and machine-independent, so CI guards it.
* ``scaling`` — weak and strong ladders over 1/2/4/8-device sub-meshes
  (``launch.mesh.make_scaling_mesh``).  steps/s is absolute (never
  guarded); the modeled collective bytes per window come from
  ``HaloSpec`` and are deterministic, so CI compares them *exactly*.
* ``collective_model`` — the HLO cross-check: compiled-program
  collective traffic (``launch.hlo_analysis``) must equal
  ``HaloSpec.window_collective_bytes`` for several (window, depth)
  schedules.  Booleans, guarded absolutely.
* ``predicted_vs_measured_mesh`` — the distributed cost model in the
  two-stage tuner: over a mesh-inclusive space every candidate is
  predicted, at most top-K are measured, and distributed rows are
  pruned analytically instead of forcing measurement.
* ``gradient_scaling`` — the distributed adjoint: same-run forward vs
  checkpointed-gradient throughput of ``st.differentiable_timeloop``'s
  engine over 1/2/4/8-device sub-meshes (CI guards the dimensionless
  ``fwd_over_grad`` ratio plus the finite-gradient / √T-checkpoint
  booleans), and the adjoint HLO cross-check: the compiled backward
  program's collective bytes must equal the *transposed* exchange
  geometry's model (``fn.spec_T.window_collective_bytes``) exactly —
  the reverse-ppermute slabs are the forward slabs, direction
  inverted, so the modeled series is guarded byte-exact like the
  forward one.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Dict

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
OUT_PATH = os.path.join(_ROOT, "BENCH_distributed.json")

_CODE = """
import json, time
import jax, numpy as np, jax.numpy as jnp
from repro.core import dsl as st, suite, autotune, cost_model
from repro.core import distributed as dist
from repro.launch import hlo_analysis
from repro.launch.mesh import make_scaling_mesh

FAST = {fast}
KERNEL = "star2d2r"
k = suite.get_kernel(KERNEL)
SWAP = suite.swap_pair(KERNEL)
HALOS = {{g: k.info.halo for g in k.ir.grid_params}}
ITEM = 4
REPS = 2 if FAST else 3
STEPS = 8 if FAST else 16
WINDOW, TS = 4, 2
STRONG = (128, 128) if FAST else (256, 256)
WEAK_LOCAL = (16, 128) if FAST else (32, 128)

assert len(jax.devices()) == 8, jax.devices()


def mk_arrays(shape, seed=0):
    gs = {{g: st.grid(np.float32, shape, k.info.order).randomize(seed + i)
          for i, g in enumerate(k.ir.grid_params)}}
    return {{g: jnp.asarray(v.data) for g, v in gs.items()}}


def time_best(fn):
    fn()                                   # warmup: compile + first run
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(list(out.values()))
        best = min(best, time.perf_counter() - t0)
    return best, out


# -- 1. fused single-program window vs per-group dispatch -------------------
mesh8 = make_scaling_mesh(8)
be_fused = st.distributed(grid_axes=("data", None), time_steps=TS)
fused_fn = dist.lower_distributed_window(k.ir, STRONG, be_fused, mesh8,
                                         SWAP, WINDOW)
be_group = st.distributed(grid_axes=("data", None), time_steps=TS,
                          swap=SWAP)
group_fn = dist.lower_distributed(k.ir, HALOS, STRONG, None, be_group, mesh8)
arrays = mk_arrays(STRONG)
scal = {{}}

def run_fused():
    a = dict(arrays)
    for _ in range(STEPS // WINDOW):
        a = fused_fn(a, scal)
    return a

def run_per_group():
    a = dict(arrays)
    for _ in range(STEPS // TS):       # one dispatched program per group
        a = group_fn(a, scal)
    return a

t_fused, out_f = time_best(run_fused)
t_group, out_g = time_best(run_per_group)
err = max(float(jnp.abs(out_f[g] - out_g[g]).max()) for g in SWAP)
assert err < 1e-5, err
fused_vs_per_window = {{
    "kernel": KERNEL, "shape": list(STRONG), "steps": STEPS,
    "window": WINDOW, "depth": TS, "devices": 8,
    "fused_seconds": t_fused, "per_window_seconds": t_group,
    "fused_steps_per_s": STEPS / t_fused,
    "per_window_steps_per_s": STEPS / t_group,
    "speedup": t_group / t_fused,
    "max_err_fused_vs_per_window": err,
}}
print("fused vs per-window:", round(fused_vs_per_window["speedup"], 2), "x",
      flush=True)


# -- 2. weak/strong scaling over 1/2/4/8-device sub-meshes ------------------
def scale_row(n, shape):
    mesh = make_scaling_mesh(n)
    fn = dist.lower_distributed_window(
        k.ir, shape, st.distributed(grid_axes=("data", None), time_steps=TS),
        mesh, SWAP, WINDOW)
    a0 = mk_arrays(shape)

    def run():
        a = dict(a0)
        for _ in range(STEPS // WINDOW):
            a = fn(a, scal)
        return a

    secs, _ = time_best(run)
    return {{
        "devices": n, "global_shape": list(shape),
        "local_shape": list(fn.local_shape),
        "seconds": secs, "steps_per_s": STEPS / secs,
        "modeled_collective_bytes_per_window":
            fn.spec.window_collective_bytes(WINDOW, ITEM),
        "modeled_collective_bytes_per_step":
            fn.spec.window_collective_bytes(WINDOW, ITEM) / WINDOW,
    }}

scaling = {{"strong": {{}}, "weak": {{}}}}
for n in (1, 2, 4, 8):
    scaling["strong"][str(n)] = scale_row(n, STRONG)
    scaling["weak"][str(n)] = scale_row(n, (WEAK_LOCAL[0] * n, WEAK_LOCAL[1]))
    print(f"scaling n={{n}}: strong "
          f"{{scaling['strong'][str(n)]['steps_per_s']:.1f}} steps/s, weak "
          f"{{scaling['weak'][str(n)]['steps_per_s']:.1f}} steps/s",
          flush=True)


# -- 3. modeled vs compiled-HLO collective bytes ----------------------------
def hlo_row(window, ts):
    be = st.distributed(grid_axes=("data", None), time_steps=ts)
    fn = dist.lower_distributed_window(k.ir, STRONG, be, mesh8, SWAP, window)
    a0 = mk_arrays(STRONG)
    interiors = {{g: a[tuple(slice(k.info.order, k.info.order + s)
                             for s in STRONG)]
                 for g, a in a0.items()}}
    hlo = fn.jitted.lower(interiors, scal).compile().as_text()
    measured = hlo_analysis.op_stats(hlo, n_devices=8).collective_bytes
    modeled = fn.spec.window_collective_bytes(window, ITEM)
    return {{"window": window, "depth": fn.depth,
             "modeled_bytes": modeled, "hlo_bytes": measured,
             "match": bool(measured == modeled)}}

collective_model = {{
    "w4_d2": hlo_row(4, 2),
    "w5_d2": hlo_row(5, 2),          # indivisible: unrolled remainder group
    "w6_d3": hlo_row(6, 3),
}}
for name, row in sorted(collective_model.items()):
    print(f"collective model {{name}}: modeled={{row['modeled_bytes']}} "
          f"hlo={{row['hlo_bytes']}} match={{row['match']}}", flush=True)


# -- 4. two-stage tuning over a mesh-inclusive space ------------------------
autotune.clear_cache()
autotune.reset_measure_count()
model = cost_model.CostModel(calibrate=False)
tune_shape = (64, 64)
grids = {{g: st.grid(st.f32, tune_shape, k.info.order).randomize(i)
         for i, g in enumerate(k.ir.grid_params)}}
dax = ("data", None)
space = [st.xla(),
         (st.distributed(grid_axes=dax), 8),
         (st.distributed(grid_axes=dax, time_steps=2), 8),
         (st.distributed(grid_axes=dax, time_steps=4), 8)]
TOP_K = 2
res = autotune.tune(k, grids, iters=1, space=space, swap=SWAP, steps=8,
                    fuse_space=(1, 8), time_block_space=(1,), top_k=TOP_K,
                    cost_model=model, mesh=mesh8)
counts = dict(autotune.MEASURE_COUNT)
measured_keys = {{(b.cache_key(), f) for b, f, _dt in res.trials}}
dist_rows = [(b, f, p) for b, f, p in res.predicted
             if getattr(b, "kind", None) == "distributed"]
dist_pruned = sum(1 for b, f, _p in dist_rows
                  if (b.cache_key(), f) not in measured_keys)
predicted_vs_measured_mesh = {{
    "kernel": KERNEL, "shape": list(tune_shape), "steps": 8,
    "candidates": len(res.predicted), "top_k": TOP_K,
    "distributed_candidates": len(dist_rows),
    "distributed_pruned": dist_pruned,
    "measured_candidates": counts["measured_candidates"],
    "pruned_candidates": counts["pruned_candidates"],
    "rank_of_measured_best": res.rank_error,
    "best_backend": str(res.backend),
    "all_candidates_predicted":
        bool(all(p is not None for _b, _f, p in res.predicted)),
    "best_in_top_k": bool(res.rank_error is not None
                          and res.rank_error < TOP_K),
    "measured_at_most_top_k":
        bool(counts["measured_candidates"] <= TOP_K),
    "distributed_pruning_active":
        bool(dist_pruned > 0
             and all(p is not None for _b, _f, p in res.predicted)),
}}
print("mesh tune: measured", counts["measured_candidates"], "of",
      len(res.predicted), "rank-of-best", res.rank_error, flush=True)


# -- 5. distributed adjoint: fwd vs gradient over sub-meshes ----------------
from repro.core import adjoint, timeloop as tl

GRAD_STEPS = 8 if FAST else 16
GRAD_WINDOW = 2


def grad_row(n):
    mesh = make_scaling_mesh(n)
    eng = tl.TimeloopEngine(
        k.ir, HALOS, STRONG,
        st.distributed(grid_axes=("data", None), time_steps=TS),
        swap=SWAP, mesh=mesh, differentiable=True)
    fn = adjoint.differentiable_run(eng, GRAD_STEPS, GRAD_WINDOW)
    arrays = mk_arrays(STRONG)

    fwd = jax.jit(lambda a: fn(a, {{}}))
    grad = jax.jit(jax.grad(lambda a: sum(jnp.sum(o ** 2)
                                          for o in fn(a, {{}}).values())))

    def time_once(f):
        jax.block_until_ready(f(arrays))       # compile + warm
        best = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(f(arrays))
            best = min(best, time.perf_counter() - t0)
        return best

    t_fwd = time_once(fwd)
    t_grad = time_once(grad)
    g = grad(arrays)
    finite = all(bool(np.isfinite(np.asarray(v)).all()) for v in g.values())
    bound = adjoint.ceil_sqrt(GRAD_STEPS // GRAD_WINDOW) + 1
    return {{
        "devices": n, "global_shape": list(STRONG), "steps": GRAD_STEPS,
        "window": GRAD_WINDOW, "depth": TS,
        "fwd_seconds": t_fwd, "grad_seconds": t_grad,
        "fwd_steps_per_s": GRAD_STEPS / t_fwd,
        "grad_steps_per_s": GRAD_STEPS / t_grad,
        "fwd_over_grad": t_fwd / t_grad,
        "checkpoints": fn.schedule["checkpoints"],
        "windows": len(fn.schedule["windows"]),
        "sqrt_checkpoint_bound": bool(fn.schedule["checkpoints"] <= bound),
        "grad_finite": finite,
    }}


def adjoint_hlo_row(window, ts):
    # collective bytes of the compiled BACKWARD program vs the transposed
    # spec's model; for this linear kernel XLA DCEs the primal chain the
    # vjp re-linearizes, leaving exactly the reverse-ppermute exchanges
    be = st.distributed(grid_axes=("data", None), time_steps=ts)
    fn = dist.lower_distributed_window(k.ir, STRONG, be, mesh8, SWAP,
                                       window, differentiable=True)
    a0 = mk_arrays(STRONG)
    interiors = {{g: a[tuple(slice(k.info.order, k.info.order + s)
                             for s in STRONG)]
                 for g, a in a0.items()}}
    cot = {{g: interiors[g] for g in SWAP}}
    hlo = fn.bwd_jitted.lower(interiors, cot, scal).compile().as_text()
    measured = hlo_analysis.op_stats(hlo, n_devices=8).collective_bytes
    modeled = fn.spec_T.window_collective_bytes(window, ITEM)
    return {{"window": window, "depth": fn.depth,
             "modeled_adjoint_bytes": modeled, "hlo_bytes": measured,
             "match": bool(measured == modeled)}}


gradient_scaling = {{
    "throughput": {{}},
    "adjoint_collective_model": {{
        "w4_d2": adjoint_hlo_row(4, 2),
        "w5_d2": adjoint_hlo_row(5, 2),
        "w6_d3": adjoint_hlo_row(6, 3),
    }},
}}
for n in (1, 2, 4, 8):
    row = grad_row(n)
    gradient_scaling["throughput"][str(n)] = row
    print(f"gradient n={{n}}: fwd {{row['fwd_steps_per_s']:.1f}} steps/s, "
          f"grad {{row['grad_steps_per_s']:.1f}} steps/s "
          f"({{row['fwd_over_grad']:.2f}}x)", flush=True)
for name, row in sorted(gradient_scaling["adjoint_collective_model"].items()):
    print(f"adjoint collective model {{name}}: "
          f"modeled={{row['modeled_adjoint_bytes']}} "
          f"hlo={{row['hlo_bytes']}} match={{row['match']}}", flush=True)

print("JSON_RESULT " + json.dumps({{
    "fused_vs_per_window": fused_vs_per_window,
    "scaling": scaling,
    "collective_model": collective_model,
    "predicted_vs_measured_mesh": predicted_vs_measured_mesh,
    "gradient_scaling": gradient_scaling,
}}))
"""


def run(fast: bool = False, verbose: bool = True) -> Dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    code = textwrap.dedent(_CODE.format(fast=repr(bool(fast))))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"distributed benchmark failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    if verbose:
        for line in r.stdout.splitlines():
            if not line.startswith("JSON_RESULT"):
                print(line, flush=True)
    payload = [l for l in r.stdout.splitlines()
               if l.startswith("JSON_RESULT")]
    results = json.loads(payload[0][len("JSON_RESULT "):])
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return results


def main():
    return run()


if __name__ == "__main__":
    main()
