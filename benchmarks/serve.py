"""Simulation-serving load benchmark: batched vs serial service of one
mixed-size request stream, plus cold-vs-warm persistent-autotune-cache
first-request latency.

Two measurements, both on the same run/machine so the guarded series are
machine-independent ratios and counters:

  * **serve_stream** — N star2d1r jobs with mixed interior shapes (all
    inside one (16, 32) pow2 bucket) and mixed step counts are served
    twice: *serially* (one unbatched fused engine per distinct request
    shape — the classic one-tenant-at-a-time path, engines reused across
    requests of the same shape) and *batched* (a ``SimServer`` packing
    waves of ``batch_cap`` scenarios into one compiled masked program).
    Reports requests/s for both, the batched-vs-serial speedup, and
    request-latency p50/p99 from the server's submit/done timestamps.
    Both paths include their compile cost — this is the cold-serve story,
    where sharing one program across the bucket is precisely the win.
  * **autotune_cache** — first-request wall time of a tuned server
    against a cold on-disk autotune cache (two-stage search: the cost
    model ranks every fuse candidate, only the ``tune_top_k`` cheapest
    are measured — ``pruned_candidates``/``pruning_factor`` report the
    saving) and against a warm one (a fresh process reading the previous
    entry).  ``warm.measured_candidates`` must be 0 and
    ``cold.measured_at_most_top_k`` must hold — the series CI asserts.

    PYTHONPATH=src python -m benchmarks.serve [--fast]
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import autotune as _at
from repro.core import cost_model as _cm
from repro.core import dsl as st
from repro.core import suite
from repro.core import timeloop as _tl
from repro.serving.stencil_serve import SimServer

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

KERNEL = "star2d1r"
#: mixed request shapes, all bucketing to (16, 32)
SHAPES: Tuple[Tuple[int, int], ...] = (
    (12, 18), (14, 20), (16, 24), (10, 28), (16, 32), (9, 17))


def _make_stream(n: int, seed: int = 0):
    """n requests cycling through SHAPES with varied step counts."""
    k = suite.get_kernel(KERNEL)
    rng = np.random.default_rng(seed)
    stream = []
    for i in range(n):
        shape = SHAPES[i % len(SHAPES)]
        steps = int(rng.integers(4, 17))
        payload = {g: rng.standard_normal(shape).astype(np.float32)
                   for g in k.ir.grid_params}
        stream.append((shape, steps, payload))
    return stream


def _serve_serial(stream) -> float:
    """One unbatched fused xla engine per distinct request shape (reused
    across the stream), each request run back-to-back."""
    k = suite.get_kernel(KERNEL)
    swap = suite.swap_pair(KERNEL)
    order = k.info.order
    engines: Dict[Tuple[int, ...], _tl.TimeloopEngine] = {}
    t0 = time.perf_counter()
    for shape, steps, payload in stream:
        eng = engines.get(shape)
        if eng is None:
            halos = {g: (order,) * k.info.ndim for g in k.ir.grid_params}
            eng = _tl.TimeloopEngine(k.ir, halos, shape, st.xla(), swap=swap)
            engines[shape] = eng
        arrays = {}
        for g in k.ir.grid_params:
            full = np.zeros(tuple(s + 2 * order for s in shape), np.float32)
            full[tuple(slice(order, order + s) for s in shape)] = payload[g]
            arrays[g] = full
        eng.run(arrays, {}, steps, 8)
    return time.perf_counter() - t0


def _serve_batched(stream, batch_cap: int):
    """The same stream through a SimServer; returns (seconds, latencies)."""
    srv = SimServer(batch_cap=batch_cap, fuse_window=8)
    t0 = time.perf_counter()
    for shape, steps, payload in stream:
        srv.submit(KERNEL, shape, steps, payload)
    done = srv.run_until_drained()
    dt = time.perf_counter() - t0
    lat = np.array([r.done_at - r.submitted_at for r in done.values()])
    return dt, lat, srv.waves_run


def _bench_stream(n_requests: int, batch_cap: int) -> Dict:
    stream = _make_stream(n_requests)
    t_serial = _serve_serial(stream)
    t_batched, lat, waves = _serve_batched(stream, batch_cap)
    return {
        "kernel": KERNEL,
        "n_requests": n_requests,
        "batch_cap": batch_cap,
        "bucket": [16, 32],
        "shapes": [list(s) for s in SHAPES],
        "waves": waves,
        "serial_seconds": t_serial,
        "batched_seconds": t_batched,
        "serial_requests_per_s": n_requests / t_serial,
        "batched_requests_per_s": n_requests / t_batched,
        "batched_vs_serial_speedup": t_serial / t_batched,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
    }


def _one_tuned_request(cache_dir: str) -> Tuple[float, int, int]:
    """Serve a single request on a tuned server as a fresh process would:
    cold in-process caches (tune results *and* cost-model calibration —
    the persisted roofline in ``cache_dir`` survives, like on disk).
    Returns (wall seconds, candidates measured, candidates pruned)."""
    _at.clear_cache()
    _at.reset_measure_count()
    _cm.reset_default_models()
    k = suite.get_kernel(KERNEL)
    rng = np.random.default_rng(7)
    shape = SHAPES[0]
    payload = {g: rng.standard_normal(shape).astype(np.float32)
               for g in k.ir.grid_params}
    srv = SimServer(batch_cap=4, autotune_cache=cache_dir)
    t0 = time.perf_counter()
    srv.submit(KERNEL, shape, 8, payload)
    srv.run_until_drained()
    dt = time.perf_counter() - t0
    return (dt, int(_at.MEASURE_COUNT["measured_candidates"]),
            int(_at.MEASURE_COUNT["pruned_candidates"]))


def _bench_autotune_cache() -> Dict:
    cdir = tempfile.mkdtemp(prefix="repro-autotune-bench-")
    try:
        cold_s, cold_n, cold_pruned = _one_tuned_request(cdir)
        warm_s, warm_n, _ = _one_tuned_request(cdir)
    finally:
        shutil.rmtree(cdir, ignore_errors=True)
    top_k = SimServer(batch_cap=1).tune_top_k
    space = cold_n + cold_pruned
    return {
        "cold": {"first_request_s": cold_s, "measured_candidates": cold_n,
                 "space_candidates": space, "top_k": top_k,
                 "pruned_candidates": cold_pruned,
                 "pruning_factor": space / cold_n if cold_n else 0.0,
                 "measured_at_most_top_k": bool(
                     top_k is None or cold_n <= top_k)},
        "warm": {"first_request_s": warm_s, "measured_candidates": warm_n},
        "warm_vs_cold_speedup": cold_s / warm_s if warm_s > 0 else 0.0,
    }


def run(fast: bool = False, verbose: bool = True) -> Dict[str, Dict]:
    results = {
        "serve_stream": _bench_stream(
            n_requests=12 if fast else 36,
            batch_cap=8),
        "autotune_cache": _bench_autotune_cache(),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    if verbose:
        s = results["serve_stream"]
        print(f"serve_stream: {s['n_requests']} requests  "
              f"serial {s['serial_requests_per_s']:.1f} req/s  "
              f"batched {s['batched_requests_per_s']:.1f} req/s  "
              f"speedup {s['batched_vs_serial_speedup']:.2f}x  "
              f"p50 {s['p50_latency_s'] * 1e3:.0f}ms  "
              f"p99 {s['p99_latency_s'] * 1e3:.0f}ms", flush=True)
        a = results["autotune_cache"]
        print(f"autotune_cache: cold {a['cold']['first_request_s']:.2f}s "
              f"({a['cold']['measured_candidates']}/"
              f"{a['cold']['space_candidates']} measured, "
              f"{a['cold']['pruned_candidates']} pruned)  "
              f"warm {a['warm']['first_request_s']:.2f}s "
              f"({a['warm']['measured_candidates']} measured)", flush=True)
        print(f"wrote {OUT_PATH}")
    return results


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    return run(fast=args.fast)


if __name__ == "__main__":
    main()
