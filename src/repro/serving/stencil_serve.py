"""Simulation-as-a-service: shape-bucketed continuous batching for stencil
jobs (the Devito-style traffic shape — many small/medium simulations from
many users, not one giant run).

A ``SimServer`` accepts (kernel-name, shape, steps, payload) requests and
serves them from a small set of compiled programs:

  1. **bucket** — requests group by ``(kernel, shape-bucket, dtype)``,
     where the bucket rounds every interior extent up to a power of two
     (``autotune.shape_bucket`` — the same bucketing the persistent
     autotune cache keys on).
  2. **pack** — up to ``batch_cap`` requests embed into one batched
     grid-set at the bucket shape.  A request's cells land at the corner
     of the bucket domain; everything outside its true sub-domain is
     *frozen* by a per-scenario spatial mask (exactly like halo cells, so
     the embedded run is bit-for-bit the small-domain run).  Waves
     shorter than the cap are padded with dummy scenarios (mask all-False,
     step budget 0) so every wave runs the same compiled program.
  3. **run** — one batched masked timeloop advances the whole wave.  The
     wave runs to the longest request's step count, rounded up to a
     multiple of the fuse window; each request freezes at its own budget
     via per-scenario step limits (``lowering.lower_jax_window_masked``).
  4. **unpack** — each request's true sub-domain is sliced back out.

Admission (``bucket_key``), packing (``pack_wave``) and unpacking
(``unpack_wave``) are pure functions; the server is a thin queue around
them.  Masked windows exist on the batched xla path only, so the server
always runs ``st.xla()`` engines — the pallas fused path would need a mask
operand threaded through the generated kernel (future work).

With ``autotune_cache=<dir>`` the server consults the persistent autotune
cache once per bucket to pick the fuse window (measuring only on a cold
cache; a warm process serves its first request with zero re-measured
candidates — see ``benchmarks/serve.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import autotune as _at
from repro.core import dsl as st
from repro.core import suite as _suite
from repro.core import timeloop as _tl

__all__ = ["SimRequest", "SimServer", "bucket_key", "pack_wave",
           "unpack_wave", "form_waves", "default_swap"]


@dataclasses.dataclass
class SimRequest:
    """One simulation job.

    ``payload`` maps grid-param name → numpy array, either the bare
    interior (``shape``) or the full halo-padded field
    (``shape + 2·order`` per axis) when the job carries boundary values.
    ``scalars`` are per-request kernel scalar parameters.  ``result``
    (set when served) maps grid name → interior array at the true shape,
    under the engine's name-rotation convention."""
    uid: int
    kernel: str
    shape: Tuple[int, ...]
    steps: int
    payload: Dict[str, np.ndarray]
    scalars: Dict[str, float] = dataclasses.field(default_factory=dict)
    dtype: str = "float32"
    submitted_at: float = 0.0
    done_at: float = 0.0
    result: Optional[Dict[str, np.ndarray]] = None


# --------------------------------------------------------------------------
# pure admission / packing / unpacking
# --------------------------------------------------------------------------
def bucket_key(kernel: str, shape: Sequence[int],
               dtype: str = "float32") -> Tuple[str, Tuple[int, ...], str]:
    """(kernel, padded-shape-bucket, dtype): requests sharing a key share
    one compiled batched program."""
    return (kernel, _at.shape_bucket(shape), str(np.dtype(dtype)))


def default_swap(k: st.Kernel) -> Optional[Tuple[str, str]]:
    """Leapfrog pair for serving: the kernel's (written, first-read) grids
    when it has exactly two grid params (every suite kernel), else None —
    multi-operand kernels must pass their pair explicitly."""
    if len(k.ir.grid_params) != 2:
        return None
    out = k.ir.output_grids()[0]
    other = next(g for g in k.ir.grid_params if g != out)
    return (out, other)


def pack_wave(k: st.Kernel, bucket: Tuple[int, ...],
              requests: Sequence[SimRequest], batch_cap: int,
              dtype="float32"):
    """Embed ≤ ``batch_cap`` requests into one batched grid-set.

    Returns ``(arrays, mask, limits)``: halo-padded ``(cap,)+bucket``
    arrays per grid, the per-scenario bool mask over the bucket interior,
    and per-scenario step budgets.  Request ``i``'s field (halo included,
    zero halos if the payload is interior-only) sits at the corner of the
    bucket domain; slots past ``len(requests)`` are dummies (mask
    all-False, budget 0) so partial waves reuse the full-cap program."""
    if len(requests) > batch_cap:
        raise ValueError(f"wave of {len(requests)} exceeds cap {batch_cap}")
    order = k.info.order
    ndim = k.info.ndim
    full = tuple(b + 2 * order for b in bucket)
    arrays = {g: np.zeros((batch_cap,) + full, dtype)
              for g in k.ir.grid_params}
    mask = np.zeros((batch_cap,) + tuple(bucket), bool)
    limits = np.zeros((batch_cap,), np.int32)
    for i, r in enumerate(requests):
        s = tuple(r.shape)
        if any(a > b for a, b in zip(s, bucket)):
            raise ValueError(f"request shape {s} exceeds bucket {bucket}")
        mask[i][tuple(slice(0, e) for e in s)] = True
        limits[i] = int(r.steps)
        sfull = tuple(e + 2 * order for e in s)
        for g in k.ir.grid_params:
            val = np.asarray(r.payload.get(g, 0.0))
            if val.ndim == 0:
                continue  # absent grid → zeros
            if tuple(val.shape) == sfull:
                idx = tuple(slice(0, e) for e in sfull)
            elif tuple(val.shape) == s:
                idx = tuple(slice(order, order + e) for e in s)
            else:
                raise ValueError(
                    f"payload '{g}' must be shape {s} (interior) or "
                    f"{sfull} (halo-padded); got {tuple(val.shape)}")
            arrays[g][(i,) + idx] = val
    return ({g: jnp.asarray(a) for g, a in arrays.items()},
            jnp.asarray(mask), jnp.asarray(limits))


def unpack_wave(k: st.Kernel, out_arrays: Mapping[str, jnp.ndarray],
                requests: Sequence[SimRequest]) -> List[Dict[str, np.ndarray]]:
    """Slice each request's true-shape interiors back out of the batched
    bucket arrays (no parity correction needed: a scenario's buffers stop
    rotating at its step budget, so names already follow the engine's
    rotation convention at exactly ``steps`` steps)."""
    order = k.info.order
    out = []
    for i, r in enumerate(requests):
        idx = tuple(slice(order, order + e) for e in r.shape)
        out.append({g: np.asarray(out_arrays[g][(i,) + idx])
                    for g in k.ir.grid_params})
    return out


def form_waves(queue: Sequence[SimRequest],
               batch_cap: int) -> List[List[SimRequest]]:
    """Split one bucket's FIFO queue into waves of ≤ ``batch_cap``."""
    return [list(queue[i:i + batch_cap])
            for i in range(0, len(queue), batch_cap)]


# --------------------------------------------------------------------------
# server
# --------------------------------------------------------------------------
class SimServer:
    """Continuous-batching front-end over the batched masked timeloop.

    ``batch_cap`` scenarios per wave (compiled once per bucket);
    ``deadline_s`` bounds how long a partially-filled wave may wait;
    ``fuse_window`` is the host-sync cadence (wave step counts round up
    to a multiple of it, so every wave reuses the same compiled window).
    ``kernels`` maps extra kernel names to ``st.Kernel`` objects (suite
    names resolve automatically); ``autotune_cache`` enables the
    persistent autotune cache directory for per-bucket fuse-window tuning.
    Cold-start tuning is two-stage: the cost model ranks the
    ``tune_fuse_space`` candidates and only the ``tune_top_k`` cheapest
    are measured (``None`` → exhaustive; ``tune_cost_model`` injects a
    pre-built ``cost_model.CostModel``).
    """

    def __init__(self, batch_cap: int = 8, deadline_s: float = 0.05,
                 fuse_window: int = 8,
                 kernels: Optional[Mapping[str, st.Kernel]] = None,
                 swaps: Optional[Mapping[str, Tuple[str, str]]] = None,
                 autotune_cache: Optional[str] = None,
                 tune_steps: int = 8,
                 tune_fuse_space: Sequence[int] = (1, 2, 4, 8, 16),
                 tune_top_k: Optional[int] = 2,
                 tune_cost_model=None):
        if batch_cap < 1:
            raise ValueError("batch_cap must be >= 1")
        self.batch_cap = int(batch_cap)
        self.deadline_s = float(deadline_s)
        self.fuse_window = int(fuse_window)
        self._kernels = dict(kernels or {})
        self._swaps = dict(swaps or {})
        self.autotune_cache = autotune_cache
        self.tune_steps = int(tune_steps)
        self.tune_fuse_space = tuple(tune_fuse_space)
        self.tune_top_k = tune_top_k
        self.tune_cost_model = tune_cost_model
        self._queues: Dict[Tuple, List[SimRequest]] = {}
        self._engines: Dict[Tuple, Tuple[_tl.TimeloopEngine, int]] = {}
        self._uid = itertools.count()
        self.waves_run = 0

    # -- kernel resolution -------------------------------------------------
    def _kernel(self, name: str) -> st.Kernel:
        k = self._kernels.get(name)
        if k is None:
            k = _suite.get_kernel(name)
            self._kernels[name] = k
        return k

    def _swap(self, name: str) -> Optional[Tuple[str, str]]:
        if name in self._swaps:
            return self._swaps[name]
        return default_swap(self._kernel(name))

    # -- submission --------------------------------------------------------
    def submit(self, kernel: str, shape: Sequence[int], steps: int,
               payload: Mapping[str, np.ndarray],
               scalars: Optional[Mapping[str, float]] = None,
               dtype: str = "float32") -> int:
        k = self._kernel(kernel)
        shape = tuple(int(s) for s in shape)
        if len(shape) != k.info.ndim:
            raise ValueError(f"kernel '{kernel}' is {k.info.ndim}D; "
                             f"got shape {shape}")
        if int(steps) < 0:
            raise ValueError("steps must be >= 0")
        r = SimRequest(uid=next(self._uid), kernel=kernel, shape=shape,
                       steps=int(steps), payload=dict(payload),
                       scalars=dict(scalars or {}), dtype=str(np.dtype(dtype)),
                       submitted_at=time.perf_counter())
        self._queues.setdefault(bucket_key(kernel, shape, dtype), []) \
            .append(r)
        return r.uid

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- engine / tuned window per bucket ----------------------------------
    def _engine_for(self, key) -> Tuple[_tl.TimeloopEngine, int]:
        entry = self._engines.get(key)
        if entry is not None:
            return entry
        name, bucket, dtype = key
        k = self._kernel(name)
        swap = self._swap(name)
        fuse = self.fuse_window
        if self.autotune_cache and swap is not None:
            # persistent-cache-backed fuse-window choice for this bucket:
            # cold processes rank the fuse candidates with the cost model
            # and measure only the tune_top_k cheapest; warm processes read
            # the tuned window from disk and measure nothing
            # (MEASURE_COUNT stays put)
            grids = {g: st.grid(st.f32, bucket, k.info.order).randomize(i)
                     for i, g in enumerate(k.ir.grid_params)}
            res = _at.tune(k, grids, iters=1, space=[st.xla()], swap=swap,
                           steps=self.tune_steps,
                           fuse_space=self.tune_fuse_space,
                           time_block_space=(1,),
                           cache_dir=self.autotune_cache,
                           top_k=self.tune_top_k,
                           cost_model=self.tune_cost_model)
            fuse = max(1, int(res.fuse_steps))
        halos = {g: (k.info.order,) * k.info.ndim for g in k.ir.grid_params}
        eng = _tl.TimeloopEngine(k.ir, halos, bucket, st.xla(), swap=swap,
                                 batch=self.batch_cap)
        self._engines[key] = (eng, fuse)
        return eng, fuse

    # -- serving loop ------------------------------------------------------
    def _ready(self, key, now: float, force: bool) -> bool:
        q = self._queues[key]
        if not q:
            return False
        if force or len(q) >= self.batch_cap:
            return True
        return (now - q[0].submitted_at) >= self.deadline_s

    def step(self, force: bool = False) -> List[SimRequest]:
        """Run at most one wave: the oldest bucket that is ready (full to
        the cap, past its deadline, or any with ``force``).  Returns the
        completed requests (empty when nothing is ready)."""
        now = time.perf_counter()
        ready = [key for key in self._queues
                 if self._ready(key, now, force)]
        if not ready:
            return []
        key = min(ready, key=lambda k2: self._queues[k2][0].submitted_at)
        q = self._queues[key]
        wave, self._queues[key] = q[:self.batch_cap], q[self.batch_cap:]
        return self._run_wave(key, wave)

    def run_until_drained(self) -> Dict[int, SimRequest]:
        """Serve everything queued (partial waves run immediately)."""
        done: Dict[int, SimRequest] = {}
        while self.pending():
            for r in self.step(force=True):
                done[r.uid] = r
        return done

    def _run_wave(self, key, wave: List[SimRequest]) -> List[SimRequest]:
        name, bucket, _dtype = key
        k = self._kernel(name)
        eng, fuse = self._engine_for(key)
        arrays, mask, limits = pack_wave(k, bucket, wave, self.batch_cap)
        # every wave runs a whole number of identical fuse windows: steps
        # round UP to a multiple of the window (per-scenario budgets stop
        # each request at its own count), so one compiled program serves
        # all step counts in the bucket
        top = max([int(r.steps) for r in wave] + [1])
        steps = -(-top // fuse) * fuse
        scal_names = [n for n, _dt in k.ir.scalar_params]
        scalars = {n: jnp.asarray([float(r.scalars.get(n, 0.0))
                                   for r in wave]
                                  + [0.0] * (self.batch_cap - len(wave)),
                                  jnp.float32)
                   for n in scal_names}
        out = eng.run(arrays, scalars, steps, fuse,
                      domain_mask=mask, step_limits=limits)
        results = unpack_wave(k, out, wave)
        now = time.perf_counter()
        for r, res in zip(wave, results):
            r.result, r.done_at = res, now
        self.waves_run += 1
        return wave
