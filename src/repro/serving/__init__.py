"""Serving substrate: KV caches (full / rolling-window / recurrent state)
and the batched decode loop."""
