"""Serving substrate: KV caches (full / rolling-window / recurrent state),
the batched LM decode loop, and the shape-bucketed stencil simulation
server (``stencil_serve.SimServer``)."""
