"""Batched serving: prefill + one-token decode steps over family-specific
caches (full KV, SWA rolling buffer, recurrent state).

``make_serve_step`` builds the jit-able single-token step the dry-run
lowers (``decode_*`` / ``long_*`` shapes); ``Generator`` drives end-to-end
greedy/temperature generation; ``BatchServer`` is a wave-scheduling batch
server (requests are grouped into fixed-size left-padded waves that share
one cache — per-slot position bookkeeping via the attention mask's
``kp >= 0`` guard on never-written slots).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass(frozen=True)
class GenConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 → greedy
    seed: int = 0


def make_serve_step(cfg: ModelConfig, sample: bool = True,
                    temperature: float = 1.0):
    """→ ``serve_step(params, cache, tokens[B,1], key) ->
    (next_tokens [B,1], cache')``.  Greedy when ``key`` is all-zero,
    temperature sampling otherwise.  With ``sample=False`` returns logits
    instead of sampled tokens."""
    temperature = max(float(temperature), 1e-6)

    def serve_step(params, cache, tokens, key):
        logits, cache2 = api.decode_step(cfg, params, cache, tokens)
        logits = logits[:, -1].astype(jnp.float32)       # [B, V]
        if not sample:
            return logits, cache2
        greedy = jnp.argmax(logits, axis=-1)
        sampled = jax.random.categorical(key, logits / temperature)
        nxt = jnp.where(jnp.all(key == 0), greedy, sampled)
        return nxt[:, None].astype(jnp.int32), cache2

    return serve_step


class Generator:
    """End-to-end generation for one batch of same-length prompts."""

    def __init__(self, cfg: ModelConfig, params, gen: GenConfig = GenConfig()):
        self.cfg, self.params, self.gen = cfg, params, gen
        self._step = jax.jit(make_serve_step(
            cfg, temperature=gen.temperature or 1.0))

    def _init_cache(self, batch: int, context_len: int):
        cache_len = api.decode_cache_len(self.cfg, context_len)
        kw = {"enc_len": 1500} if self.cfg.family == "audio" else {}
        return api.init_cache(self.cfg, batch, cache_len, **kw)

    def generate(self, prompts: np.ndarray,
                 frame_embeds: Optional[np.ndarray] = None,
                 max_new: Optional[int] = None) -> np.ndarray:
        """prompts: [B, S] int32 → [B, S + max_new] (greedy when
        temperature == 0).  ``max_new`` overrides the config's
        ``max_new_tokens`` per call (the batch server varies it per wave
        without rebuilding the generator)."""
        cfg, gen = self.cfg, self.gen
        if max_new is not None:
            gen = dataclasses.replace(gen, max_new_tokens=int(max_new))
        B, S = prompts.shape
        ctx = S + gen.max_new_tokens
        cache = self._init_cache(B, ctx)
        if cfg.family == "audio":
            enc = api.module_for(cfg).encode(
                self.params, jnp.asarray(frame_embeds), cfg)
            from repro.models import encdec
            cache = encdec.build_cache(self.params, enc, cfg, B,
                                       api.decode_cache_len(cfg, ctx))

        toks = jnp.asarray(prompts, jnp.int32)
        key = jax.random.PRNGKey(gen.seed)
        out = [toks]
        # feed the prompt token-by-token (universal prefill; family-
        # specific fast prefill lives in models/*.prefill)
        cur = toks[:, :1]
        for t in range(S + gen.max_new_tokens - 1):
            if gen.temperature > 0:
                key, sub = jax.random.split(key)
            else:
                sub = jnp.zeros((2,), jnp.uint32)
            nxt, cache = self._step(self.params, cache, cur, sub)
            if t + 1 < S:
                cur = toks[:, t + 1:t + 2]      # teacher-force the prompt
            else:
                cur = nxt
                out.append(nxt)
        return np.asarray(jnp.concatenate(out, axis=1))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int
    result: Optional[np.ndarray] = None
    submitted_at: float = 0.0
    done_at: float = 0.0


class BatchServer:
    """Wave-scheduling batch server.

    Pending requests are grouped into waves of ``batch_size``; each wave is
    left-padded and generated together.  To keep XLA from recompiling the
    decode step on every wave, each wave's context length
    (``S + max_new_tokens``) is bucketed up to the next power of two and
    the batch is padded to the full ``batch_size`` with dummy slots — so
    all waves whose context falls in one bucket share a single compiled
    step (see ``test_batch_server_single_compile``).
    (A shared scalar cache position keeps the step fully static — the
    continuous-batching upgrade is per-slot positions, noted in DESIGN.md.)
    """

    def __init__(self, cfg: ModelConfig, params, batch_size: int = 8,
                 gen: GenConfig = GenConfig()):
        self.cfg, self.params = cfg, params
        self.batch_size = batch_size
        self.gen = gen
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._uid = 0
        self._generator = Generator(cfg, params, gen)

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_new_tokens, submitted_at=time.time()))
        return self._uid

    def step(self) -> List[int]:
        """Serve one wave; returns finished uids."""
        if not self.queue:
            return []
        wave = self.queue[:self.batch_size]
        self.queue = self.queue[self.batch_size:]
        S = max(len(r.prompt) for r in wave)
        mx = max(r.max_new_tokens for r in wave)
        # bucket the context (prompt + generation) to the next power of
        # two and pad the batch to ``batch_size`` — the decode step's
        # (B, cache_len) signature is then wave-invariant per bucket
        ctx = 1 << max(1, (S + mx - 1).bit_length())
        Sb = ctx - mx
        toks = np.zeros((self.batch_size, Sb), np.int32)
        for i, r in enumerate(wave):
            toks[i, Sb - len(r.prompt):] = r.prompt     # left padding
        out = self._generator.generate(toks, max_new=mx)
        finished = []
        for i, r in enumerate(wave):
            r.result = out[i, Sb:Sb + r.max_new_tokens]
            r.done_at = time.time()
            self.done[r.uid] = r
            finished.append(r.uid)
        return finished

    def run_until_drained(self) -> Dict[int, Request]:
        while self.queue:
            self.step()
        return self.done
