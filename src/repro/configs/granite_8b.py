"""Granite 8B (code) — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152, llama-arch SwiGLU [arXiv:2405.04324; hf].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    act="swiglu",
    rope_theta=10000.0,
    attn_chunk=1024,
    logits_chunk=1024,
))
