"""Mixtral 8x22B — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf].
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    act="swiglu",
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25,
                  group_size=4096),
    rope_theta=1e6,
    attn_chunk=1024,
    logits_chunk=None,
))
