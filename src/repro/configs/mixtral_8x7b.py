"""Mixtral 8x7B — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096) [arXiv:2401.04088; hf].
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    act="swiglu",
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25,
                  group_size=4096),
    rope_theta=1e6,
    attn_chunk=1024,
    logits_chunk=None,
))
