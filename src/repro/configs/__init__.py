"""Architecture registry: one module per assigned architecture (+ shapes).

``repro.configs.get("mixtral-8x7b")`` → ModelConfig;
``repro.configs.shapes.SHAPES`` → the assigned input shapes.
"""
from .base import ModelConfig, MoEConfig, get, names, register, tiny  # noqa: F401

# one module per assigned architecture — importing registers the config
from . import (  # noqa: F401
    mixtral_8x7b, mixtral_8x22b, granite_8b, gemma_7b, phi3_mini,
    nemotron_4_15b, recurrentgemma_9b, xlstm_1_3b, pixtral_12b,
    whisper_small,
)
from . import shapes  # noqa: F401
from .shapes import SHAPES, applicable, input_specs  # noqa: F401

ARCH_NAMES = (
    "mixtral-8x7b", "mixtral-8x22b", "granite-8b", "gemma-7b",
    "phi3-mini-3.8b", "nemotron-4-15b", "recurrentgemma-9b", "xlstm-1.3b",
    "pixtral-12b", "whisper-small",
)
