"""xLSTM 1.3B — 48 blocks d_model=2048 4H (kv=4) vocab=50304,
mLSTM blocks with sLSTM every 8th (7:1 ratio) [arXiv:2405.04517;
unverified].  d_ff=0: xLSTM blocks have no separate FFN.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    act="gelu",
    slstm_every=8,
    chunk=256,
    tie_embeddings=True,
    logits_chunk=1024,
))
