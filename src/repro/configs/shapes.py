"""Assigned input shapes × architecture → abstract input specs.

Every (arch × shape) cell of the assignment is made concrete here:

  train_4k      seq_len=4096    global_batch=256   (training step)
  prefill_32k   seq_len=32768   global_batch=32    (inference prefill)
  decode_32k    seq_len=32768   global_batch=128   (one-token decode, KV
                                                    cache of seq_len)
  long_500k     seq_len=524288  global_batch=1     (long-context decode;
                                                    sub-quadratic archs only)

``input_specs(cfg, shape)`` returns ``jax.ShapeDtypeStruct`` stand-ins for
every model input — weak-type-correct, shardable, no device allocation —
exactly what ``launch/dryrun.py`` lowers against.

Modality frontends are stubs per the assignment: ``[vlm]`` cells provide
precomputed patch embeddings, ``[audio]`` cells precomputed frame
embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "applicable", "input_specs",
           "batch_dims", "make_host_batch"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def _subquadratic(cfg: ModelConfig) -> bool:
    """Archs with O(window)/O(1) decode state: SSM, hybrid, or SWA."""
    return cfg.family in ("ssm", "hybrid") or bool(cfg.window)


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skip).  Skips follow DESIGN.md §Arch-applicability:
    ``long_500k`` needs sub-quadratic attention; pure full-attention archs
    skip it.  (No encoder-only archs are assigned, so decode shapes run
    everywhere else.)"""
    if shape.name == "long_500k" and not _subquadratic(cfg):
        return False, ("pure full-attention arch: 500k-context decode has "
                       "no sub-quadratic structure (documented skip)")
    return True, ""


def _embed_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Abstract inputs for the step function of this cell.

    train   → the loss batch {tokens, labels, [patch_embeds|frame_embeds]}
    prefill → {tokens, [patch_embeds|frame_embeds]}
    decode  → {tokens [B,1], cache}
    """
    from repro.models import api  # local import to avoid cycles

    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = _embed_dtype(cfg)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            # enc-dec split: enc_frames = dec_tokens = S/2 (DESIGN.md §5)
            T = S // 2
            specs = {"frame_embeds": jax.ShapeDtypeStruct((B, T, cfg.d_model), dt),
                     "tokens": jax.ShapeDtypeStruct((B, T), i32)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
            return specs
        if cfg.family == "vlm":
            P = cfg.n_prefix_tokens
            St = S - P
            specs = {"patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), dt),
                     "tokens": jax.ShapeDtypeStruct((B, St), i32)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, St), i32)
            return specs
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs

    # decode: one new token against a cache of context S
    cache_len = api.decode_cache_len(cfg, S)
    kw = {}
    if cfg.family == "audio":
        kw["enc_len"] = 1500  # fixed whisper encoder output length
    cache = api.cache_spec(cfg, B, cache_len, **kw)
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32), "cache": cache}


def batch_dims(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, int]:
    """Leading batch dim of every input-spec leaf group (for sharding)."""
    return {"tokens": 0, "labels": 0, "patch_embeds": 0, "frame_embeds": 0}


def make_host_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Concrete (small!) host arrays matching ``input_specs`` — only for
    reduced smoke configs; never call on full configs."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        if name == "cache":
            from repro.models import api
            cache_len = api.decode_cache_len(cfg, shape.seq_len)
            kw = {"enc_len": 1500} if cfg.family == "audio" else {}
            out[name] = api.init_cache(cfg, shape.global_batch, cache_len, **kw)
        elif spec.dtype == jnp.int32:
            out[name] = rng.integers(0, cfg.vocab, spec.shape).astype(np.int32)
        else:
            out[name] = rng.standard_normal(spec.shape).astype(np.float32)
    return out
