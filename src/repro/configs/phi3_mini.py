"""Phi-3-mini 3.8B — 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064,
RoPE SwiGLU [arXiv:2404.14219; unverified].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    act="swiglu",
    rope_theta=10000.0,
    attn_chunk=1024,
    logits_chunk=None,
))
