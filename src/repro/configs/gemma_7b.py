"""Gemma 7B — 28L d_model=3072 16H (kv=16, i.e. MHA) d_ff=24576
vocab=256000, GeGLU, head_dim=256 [arXiv:2403.08295; hf].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    attn_chunk=1024,
    logits_chunk=256,
))
