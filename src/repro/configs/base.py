"""Model/config registry for the assigned architectures (+ the paper's own
stencil workloads live under repro.core / examples).

Every architecture is a ``ModelConfig``; ``repro.configs.get(name)`` returns
it and ``tiny()`` derives the reduced smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

_REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 2048          # dispatch-einsum token group
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"             # swiglu | geglu | sqrelu | gelu
    norm: str = "rmsnorm"
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None    # sliding-window attention size
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # hybrid (griffin): repeating block pattern, e.g. ("rec","rec","attn")
    block_pattern: Optional[Tuple[str, ...]] = None
    rnn_width: Optional[int] = None       # RG-LRU recurrence width
    conv_width: int = 4                   # temporal conv width (griffin)
    local_window: Optional[int] = None    # griffin local-attn window
    # ssm (xlstm)
    slstm_every: Optional[int] = None     # one sLSTM block every N layers
    chunk: int = 256                      # chunkwise-recurrence chunk length
    # enc-dec (whisper)
    n_enc_layers: Optional[int] = None
    n_dec_layers: Optional[int] = None
    # vlm (pixtral)
    n_prefix_tokens: int = 0              # patch-embedding prefix (stub)
    # numerics / perf knobs
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    attn_chunk: Optional[int] = None      # blockwise-attention KV chunk
    logits_chunk: Optional[int] = None    # vocab-chunked loss (hillclimb)
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers is not None

    # Exact parameter counts are computed from the real init shape-tree by
    # ``repro.models.api.param_count(cfg)`` — no duplicate bookkeeping here.


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ModelConfig:
    # import the arch modules lazily so `get` works without preimports
    from repro import configs as _c  # noqa: F401  (triggers registration)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def tiny(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-tiny",
        n_layers=(4 if cfg.slstm_every
                  else min(cfg.n_layers, 2 * len(cfg.block_pattern or (1,)))),
        slstm_every=2 if cfg.slstm_every else None,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        head_dim=16,
        window=min(cfg.window, 32) if cfg.window else None,
        local_window=min(cfg.local_window, 16) if cfg.local_window else None,
        rnn_width=64 if cfg.rnn_width else None,
        # capacity_factor = n_experts ⇒ dropless in both the training and
        # decode groupings, so decode-vs-forward equivalence is exact
        moe=dataclasses.replace(cfg.moe, n_experts=4, top_k=2, group_size=64,
                                capacity_factor=4.0)
        if cfg.moe else None,
        n_enc_layers=2 if cfg.n_enc_layers else None,
        n_dec_layers=2 if cfg.n_dec_layers else None,
        n_prefix_tokens=8 if cfg.n_prefix_tokens else 0,
        chunk=16,
        attn_chunk=None,
        remat=False,
    )
    return dataclasses.replace(cfg, **kw)
