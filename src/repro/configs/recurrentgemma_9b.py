"""RecurrentGemma 9B (Griffin) — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, RG-LRU + local attention 1:2 pattern
[arXiv:2402.19427; unverified].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    rnn_width=4096,
    conv_width=4,
    local_window=2048,
    rope_theta=10000.0,
    attn_chunk=1024,
    logits_chunk=256,
))
