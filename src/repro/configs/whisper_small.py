"""Whisper small — 12L enc + 12L dec, d_model=768 12H (kv=12) d_ff=3072
vocab=51865, encoder-decoder with conv frontend (STUB: ``input_specs``
provides precomputed frame embeddings; kernels/conv1d demonstrates the
real op) [arXiv:2212.04356; unverified].
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=24,                    # total (12 enc + 12 dec) for bookkeeping
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=10000.0,
    attn_chunk=1024,
    logits_chunk=1024,
))
