"""Pixtral 12B — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
Mistral-Nemo backbone with Pixtral-ViT frontend (STUB: ``input_specs``
provides precomputed patch embeddings) [hf:mistralai/Pixtral-12B-2409;
unverified].  head_dim=128 (explicit, not d_model/n_heads).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    act="swiglu",
    n_prefix_tokens=1024,           # 32x32-patch image prefix (stub)
    rope_theta=1e6,
    attn_chunk=1024,
    logits_chunk=512,
))
