"""Lower StencilIR to pure-jnp shifted-slice code (the ``xla`` backend).

This is the portable, always-correct lowering — the analogue of the paper's
reference OpenMP backend — and doubles as the oracle every Pallas kernel is
validated against (``kernels/stencil/ref.py`` re-exports it).

The lowering turns each ``Tap(grid, offsets)`` into a static ``lax.slice`` of
the (halo-padded) grid array and evaluates the expression tree vectorized
over the whole region at once; XLA fuses the result into a single elementwise
loop over the grid.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import ir

_MATH = {
    "exp": jnp.exp, "sqrt": jnp.sqrt, "abs": jnp.abs, "sin": jnp.sin,
    "cos": jnp.cos, "tanh": jnp.tanh, "min": jnp.minimum, "max": jnp.maximum,
}


def eval_expr(e: ir.Expr, read: Callable[[str, Tuple[int, ...]], jnp.ndarray],
              scalars: Mapping[str, jnp.ndarray], local_env: Dict[str, jnp.ndarray]):
    """Evaluate an IR expression with a pluggable tap-``read`` function.

    Shared by this lowering, the Pallas code generators, and the distributed
    backend — each supplies its own ``read`` (slice / VMEM ref / halo view).
    """
    if isinstance(e, ir.Const):
        return e.value
    if isinstance(e, ir.ScalarRef):
        return scalars[e.name]
    if isinstance(e, ir.LocalRef):
        return local_env[e.name]
    if isinstance(e, ir.Tap):
        return read(e.grid, e.offsets)
    if isinstance(e, ir.Neg):
        return -eval_expr(e.operand, read, scalars, local_env)
    if isinstance(e, ir.BinOp):
        l = eval_expr(e.lhs, read, scalars, local_env)
        r = eval_expr(e.rhs, read, scalars, local_env)
        if e.op == "+":
            return l + r
        if e.op == "-":
            return l - r
        if e.op == "*":
            return l * r
        if e.op == "/":
            return l / r
        if e.op == "**":
            return l ** r
        raise ValueError(f"bad op {e.op}")
    if isinstance(e, ir.Call):
        args = [eval_expr(a, read, scalars, local_env) for a in e.args]
        return _MATH[e.fn](*args)
    raise TypeError(f"bad expr {e!r}")


def run_statements(kernel: ir.StencilIR,
                   read_from: Callable[[jnp.ndarray, str, Tuple[int, ...]], jnp.ndarray],
                   arrays: Dict[str, jnp.ndarray],
                   scalars: Mapping[str, jnp.ndarray],
                   write: Callable[[jnp.ndarray, jnp.ndarray, str], jnp.ndarray],
                   region_shape: Tuple[int, ...],
                   dtype) -> Dict[str, jnp.ndarray]:
    """Execute kernel statements sequentially over ``arrays`` (functional)."""
    local_env: Dict[str, jnp.ndarray] = {}
    arrays = dict(arrays)

    def read(g, offs):
        return read_from(arrays[g], g, offs)

    for stmt in kernel.body:
        if isinstance(stmt, ir.LocalDef):
            local_env[stmt.name] = eval_expr(stmt.expr, read, scalars, local_env)
        else:
            val = eval_expr(stmt.expr, read, scalars, local_env)
            val = jnp.broadcast_to(jnp.asarray(val, dtype), region_shape)
            arrays[stmt.grid] = write(arrays[stmt.grid], val, stmt.grid)
    return arrays


def lower_jax(kernel: ir.StencilIR,
              halos: Mapping[str, Tuple[int, ...]],
              interior_shape: Tuple[int, ...],
              region: Optional[Tuple[Tuple[int, int], ...]] = None):
    """Build ``fn(arrays: dict, scalars: dict) -> dict`` for this kernel.

    arrays map grid-param name → full (halo-padded) jnp array; the function
    returns the dict with output grids updated on ``region`` (interior
    coordinates, default the whole interior).  Pure and jittable.
    """
    ndim = kernel.ndim
    if region is None:
        region = tuple((0, s) for s in interior_shape)
    region_shape = tuple(e - b for b, e in region)

    def read_from(arr, g, offs):
        h = halos[g]
        idx = tuple(
            slice(h[ax] + region[ax][0] + offs[ax],
                  h[ax] + region[ax][1] + offs[ax])
            for ax in range(ndim)
        )
        return arr[idx]

    def write(arr, val, g):
        h = halos[g]
        idx = tuple(
            slice(h[ax] + region[ax][0], h[ax] + region[ax][1])
            for ax in range(ndim)
        )
        return arr.at[idx].set(val)

    def fn(arrays: Dict[str, jnp.ndarray], scalars: Mapping[str, jnp.ndarray]):
        dtype = arrays[kernel.output_grids()[0]].dtype
        return run_statements(kernel, read_from, arrays, scalars, write,
                              region_shape, dtype)

    return fn


def lower_jax_window(kernel: ir.StencilIR,
                     halos: Mapping[str, Tuple[int, ...]],
                     interior_shape: Tuple[int, ...],
                     region: Optional[Tuple[Tuple[int, int], ...]],
                     swap: Optional[Tuple[str, str]],
                     steps: int,
                     *,
                     remat: bool = False):
    """Fused time-loop window on the XLA backend: ``steps`` applications of
    the kernel plus the leapfrog buffer rotation, executed inside a single
    ``lax.fori_loop`` program (one compiled call per fusion window instead
    of one per time step — no host sync, no per-step dict repack).

    ``swap`` is the (written, other) grid pair whose buffers rotate after
    each application (None → no rotation).  Returns
    ``fn(arrays, scalars) -> arrays`` — pure and jittable, so the caller
    can donate the input buffers.

    The window is reverse-mode differentiable: the trip count is static,
    so the ``fori_loop`` lowers to a ``scan`` whose VJP stores one carry
    per step.  ``remat=True`` additionally wraps the per-step kernel in
    ``jax.checkpoint`` so the backward pass recomputes tap intermediates
    from each step's carry instead of saving them — the configuration the
    adjoint engine (``core/adjoint.py``) uses for its per-window VJPs,
    keeping window residuals at one leapfrog carry per step.
    """
    step_fn = lower_jax(kernel, halos, interior_shape, region)
    if remat:
        step_fn = jax.checkpoint(step_fn)

    def window(arrays: Dict[str, jnp.ndarray],
               scalars: Mapping[str, jnp.ndarray]):
        def body(_, arrs):
            out = step_fn(arrs, scalars)
            if swap is not None:
                out = dict(out)
                out[swap[0]], out[swap[1]] = out[swap[1]], out[swap[0]]
            return out
        return lax.fori_loop(0, steps, body, dict(arrays))

    return window


def lower_jax_window_masked(kernel: ir.StencilIR,
                            halos: Mapping[str, Tuple[int, ...]],
                            interior_shape: Tuple[int, ...],
                            swap: Optional[Tuple[str, str]],
                            steps: int,
                            *,
                            remat: bool = False):
    """Masked fused window for shape-bucketed serving: the step update is
    confined to a ``mask``-selected sub-domain and to scenarios whose step
    budget has not run out.

    Semantics (exact, not approximate):

      * **spatial** — interior cells where ``mask`` is False are *frozen*:
        they keep each buffer's original value forever and behave exactly
        like grid-halo cells.  Embedding a smaller request (its own halo
        values included) into a larger bucket grid therefore reproduces
        the small-domain run bit-for-bit — taps only ever reach ``h`` deep
        into the frozen region, where the request's own halo values live.
      * **temporal** — the window runs ``steps`` applications, but a
        scenario stops changing (buffer rotation included) once the global
        step index ``start + i`` reaches its ``limit``.  A wave can thus
        run to the longest request's step count while shorter requests
        freeze at theirs, with no name-parity correction needed at unpack
        time.

    Returns ``fn(arrays, scalars, mask, start, limit) -> arrays`` where
    ``mask`` is a bool array over the interior, ``start`` the global index
    of the window's first step, and ``limit`` the scenario's step budget.
    ``start`` is shared across a vmapped batch (in_axes=None); ``mask``
    and ``limit`` are per-scenario.

    The freeze semantics are expressed with ``where``/``at.set`` selects,
    so the window's *adjoint* freezes masked cells too: reverse-mode
    differentiation routes a frozen cell's cotangent straight through the
    step (identity — its value never changed) while the computed-but-
    discarded update contributes nothing, and a budget-exhausted scenario
    back-propagates the identity as well (no rotation, no update).  The
    mask, start, and limit operands are non-differentiable (bool / int)
    and receive no cotangent.  ``remat`` as in ``lower_jax_window``.
    """
    step_fn = lower_jax(kernel, halos, interior_shape, None)
    if remat:
        step_fn = jax.checkpoint(step_fn)
    written = kernel.output_grids()
    ndim = kernel.ndim

    def interior_idx(g):
        h = halos[g]
        return tuple(slice(h[ax], h[ax] + interior_shape[ax])
                     for ax in range(ndim))

    def window(arrays: Dict[str, jnp.ndarray],
               scalars: Mapping[str, jnp.ndarray],
               mask: jnp.ndarray,
               start: jnp.ndarray,
               limit: jnp.ndarray):
        def body(i, arrs):
            out = dict(step_fn(arrs, scalars))
            act = (start + i) < limit
            # spatial freeze in *buffer* space (before rotation), so frozen
            # cells travel with their buffers exactly like halo cells do
            for g in written:
                idx = interior_idx(g)
                out[g] = arrs[g].at[idx].set(
                    jnp.where(mask, out[g][idx], arrs[g][idx]))
            if swap is not None:
                w, o = swap
                # per-scenario rotation: a frozen scenario keeps both
                # buffers (no rotation), an active one trades them
                new_w = jnp.where(act, arrs[o], arrs[w])
                new_o = jnp.where(act, out[w], arrs[o])
                out[w], out[o] = new_w, new_o
            else:
                for g in written:
                    out[g] = jnp.where(act, out[g], arrs[g])
            return out
        return lax.fori_loop(0, steps, body, dict(arrays))

    return window
