"""StencilIR: the intermediate representation produced by the DSL frontend.

The paper (§4.4) parses DSL code into an AST, then lowers it to a sequence of
IRs annotated with stencil shape / looping pattern / grid updates.  We keep a
single typed IR that captures everything the analyses and code generators
need:

  * ``Tap``        — a read of a grid at a constant integer offset from the
                     current stencil point (``u.at(-4, 0)``).
  * ``Assign``     — an update of a grid at the center point
                     (``v.at(0, 0).set(expr)``).
  * ``LocalDef``   — a local temporary (``lap = ...``) usable by later
                     statements; enables multi-statement stencils such as the
                     acoustic-ISO update.
  * expression nodes: ``Const``, ``ScalarRef``, ``LocalRef``, ``BinOp``,
    ``Neg``, ``Call`` (a small whitelisted math-function set).

Offsets must be compile-time integer constants — this is what makes the
stencil *shape* statically analyzable, which is the property the whole
paper's template machinery rests on.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


class Expr:
    """Base class for StencilIR expressions (frozen dataclasses below)."""

    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float


@dataclasses.dataclass(frozen=True)
class ScalarRef(Expr):
    """Reference to a scalar kernel parameter (``st.f32``/``st.i32``)."""

    name: str


@dataclasses.dataclass(frozen=True)
class LocalRef(Expr):
    """Reference to a ``LocalDef`` temporary."""

    name: str


@dataclasses.dataclass(frozen=True)
class Tap(Expr):
    """Read grid ``grid`` at constant ``offsets`` from the center point."""

    grid: str
    offsets: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str  # '+', '-', '*', '/', '**'
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True)
class Neg(Expr):
    operand: Expr


@dataclasses.dataclass(frozen=True)
class Call(Expr):
    """Whitelisted elementwise math call (exp, sqrt, abs, min, max...)."""

    fn: str
    args: Tuple[Expr, ...]


# --------------------------------------------------------------------------
# Statements
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LocalDef:
    name: str
    expr: Expr


@dataclasses.dataclass(frozen=True)
class Assign:
    """``grid.at(0, ..).set(expr)`` — center-point update.

    ``offsets`` is retained for generality but non-zero write offsets are
    rejected by the frontend (stencils write the center point; this is also
    what makes the map parallel).
    """

    grid: str
    offsets: Tuple[int, ...]
    expr: Expr


Stmt = Union[LocalDef, Assign]


@dataclasses.dataclass(frozen=True)
class StencilIR:
    """A parsed stencil kernel.

    grid_params   : names of grid parameters in positional order
    scalar_params : (name, dtype-str) of scalar parameters in positional order
    ndim          : dimensionality of every ``at`` offset tuple
    body          : statements in program order
    """

    name: str
    ndim: int
    grid_params: Tuple[str, ...]
    scalar_params: Tuple[Tuple[str, str], ...]
    body: Tuple[Stmt, ...]

    # -- convenience ------------------------------------------------------
    def walk_exprs(self):
        """Yield every expression node in the body (pre-order)."""

        def _walk(e):
            yield e
            if isinstance(e, BinOp):
                yield from _walk(e.lhs)
                yield from _walk(e.rhs)
            elif isinstance(e, Neg):
                yield from _walk(e.operand)
            elif isinstance(e, Call):
                for a in e.args:
                    yield from _walk(a)

        for stmt in self.body:
            yield from _walk(stmt.expr)

    def taps(self):
        return [e for e in self.walk_exprs() if isinstance(e, Tap)]

    def output_grids(self) -> Tuple[str, ...]:
        seen = []
        for stmt in self.body:
            if isinstance(stmt, Assign) and stmt.grid not in seen:
                seen.append(stmt.grid)
        return tuple(seen)

    def input_grids(self) -> Tuple[str, ...]:
        seen = []
        for t in self.taps():
            if t.grid not in seen:
                seen.append(t.grid)
        return tuple(seen)
