"""Acoustic isotropic wave propagation (paper §2.2 / §6.2) as a StencilPy
application: 25-point star stencil (8th order in space, 2nd order in time),
PML absorbing boundaries, per-iteration source perturbation.

Update (leapfrog with damping η = damp·dt, unified-domain form — PML folded
in as a coefficient field so the same kernel covers inner + PML regions;
regions.py provides the 2/7-region decomposition alternative):

    p_next = (2·p1 − (1−η)·p0 + (vp²·dt²)·Δ₈p1) / (1+η)

Δ₈ is the 8th-order 25-point star Laplacian (unit grid spacing; the dx
scaling is folded into vp²·dt²).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import dsl as st
from . import regions

# 8th-order central second-derivative coefficients
C0 = -205.0 / 72.0
C1 = 8.0 / 5.0
C2 = -1.0 / 5.0
C3 = 8.0 / 315.0
C4 = -1.0 / 560.0
ORDER = 4


@st.kernel
def acoustic_iso_kernel(p0: st.grid, p1: st.grid, vp2: st.grid,
                        damp: st.grid, dt: st.f32):
    lap = (3.0 * -2.8472222 * p1.at(0, 0, 0)
           + 1.6 * (p1.at(-1, 0, 0) + p1.at(1, 0, 0)
                    + p1.at(0, -1, 0) + p1.at(0, 1, 0)
                    + p1.at(0, 0, -1) + p1.at(0, 0, 1))
           - 0.2 * (p1.at(-2, 0, 0) + p1.at(2, 0, 0)
                    + p1.at(0, -2, 0) + p1.at(0, 2, 0)
                    + p1.at(0, 0, -2) + p1.at(0, 0, 2))
           + 0.025396825 * (p1.at(-3, 0, 0) + p1.at(3, 0, 0)
                            + p1.at(0, -3, 0) + p1.at(0, 3, 0)
                            + p1.at(0, 0, -3) + p1.at(0, 0, 3))
           - 0.0017857143 * (p1.at(-4, 0, 0) + p1.at(4, 0, 0)
                             + p1.at(0, -4, 0) + p1.at(0, 4, 0)
                             + p1.at(0, 0, -4) + p1.at(0, 0, 4)))
    p0.at(0, 0, 0).set(
        (2.0 * p1.at(0, 0, 0)
         - (1.0 - damp.at(0, 0, 0) * dt) * p0.at(0, 0, 0)
         + vp2.at(0, 0, 0) * dt * dt * lap)
        / (1.0 + damp.at(0, 0, 0) * dt))


def make_fields(shape: Tuple[int, int, int], pml_width: int = 10,
                vp: float = 1.5, dt: float = 0.3,
                damp_strength: float = 0.2):
    """Build (p0, p1, vp2, damp) grids for a domain of ``shape`` interior
    points.  vp in km/s-ish units; dt chosen CFL-stable for vp=1.5."""
    g = lambda: st.grid(dtype=st.f32, shape=shape, order=ORDER)  # noqa: E731
    p0, p1 = g(), g()
    vp2 = g()
    vp2.interior = jnp.full(shape, vp * vp, jnp.float32)
    damp = g()
    damp.interior = regions.damping_mask(shape, pml_width,
                                         strength=damp_strength)
    return p0, p1, vp2, damp, np.float32(dt)


def source_wavelet(t: int, f0: float = 0.015, t0: int = 40) -> float:
    """Ricker wavelet sample at integer time step t."""
    a = (np.pi * f0 * (t - t0)) ** 2
    return float((1.0 - 2.0 * a) * np.exp(-a))


def inject_source(p: st.grid, t: int, pos: Optional[Tuple[int, ...]] = None,
                  amp: float = 1.0) -> None:
    """Paper §6.2: 'simulates the source perturbation after each time
    iteration' — add a wavelet sample at the source point."""
    if pos is None:
        pos = tuple(s // 2 for s in p.shape)
    o = p.order
    idx = tuple(o + q for q in pos)
    p.data = p.data.at[idx].add(amp * source_wavelet(t))


@st.target
def acoustic_target(p0: st.grid, p1: st.grid, vp2: st.grid, damp: st.grid,
                    dt: st.f32, iters: st.i32):
    """Time loop: stencil update + buffer swap (source injection is done by
    the caller between launches, matching the paper's host-side driver)."""
    for _t in range(iters):
        st.map(e=p0.shape)(acoustic_iso_kernel)(p0, p1, vp2, damp, dt)
        (p0.data, p1.data) = (p1.data, p0.data)


@st.target
def acoustic_target_fused(p0: st.grid, p1: st.grid, vp2: st.grid,
                          damp: st.grid, dt: st.f32, iters: st.i32,
                          between=None):
    """Fused time loop: the whole step sequence (update + swap) runs as a
    single compiled program per fusion window (``st.launch(...,
    fuse_steps=K)``), syncing with the host — and running ``between`` for
    source injection — only at window boundaries."""
    return st.timeloop(iters, swap=("p0", "p1"), between=between)(
        acoustic_iso_kernel)(p0, p1, vp2, damp, dt)


def run(shape=(64, 64, 64), iters: int = 10, backend=None, mesh=None,
        pml_width: int = 8, with_source: bool = True,
        fuse_steps: int = None):
    """Convenience driver used by examples/benchmarks.  Returns
    (final wavefield grid, launch profile).

    ``fuse_steps`` switches to the fused time-loop engine: per-step host
    work (and source injection) collapses to fusion-window boundaries, so
    the wavelet is injected every ``fuse_steps`` steps instead of every
    step — identical when ``fuse_steps=1``, a documented approximation of
    the forcing term otherwise (the stencil math itself is unchanged).
    """
    p0, p1, vp2, damp, dt = make_fields(shape, pml_width=pml_width)
    backend = backend or st.xla()
    if fuse_steps is not None:
        if with_source:
            inject_source(p1, 0)

            def between(t, grids):
                inject_source(grids["p1"], t)
        else:
            between = None
        res = st.launch(backend=backend, mesh=mesh, fuse_steps=fuse_steps)(
            acoustic_target_fused)(p0, p1, vp2, damp, dt, iters,
                                   between=between)
        return p1, res.profile
    total_prof = {}
    for t in range(iters):
        if with_source:
            inject_source(p1, t)
        res = st.launch(backend=backend, mesh=mesh)(acoustic_target)(
            p0, p1, vp2, damp, dt, 1)
        for k, v in res.profile.items():
            total_prof[k] = total_prof.get(k, 0.0) + v
    return p1, total_prof
