"""Inner/PML region bookkeeping (paper §2.2, Table 3 'domain decompositions').

Seismic modeling surrounds the computational domain with a Perfectly-Matched
Layer.  The paper's framework "decomposes the data domain and launches
dedicated kernels accordingly":

* ``unified``      — one kernel over the whole domain (PML damping folded in
                     as a coefficient field, zero inside).  The only form
                     supported by the distributed backend (masks, no
                     per-region launches).
* ``two_region``   — inner box + the PML shell (returned as disjoint boxes,
                     launched with the same PML kernel).
* ``seven_region`` — 3-D: inner box + 6 face slabs (2-D: 1 + 4 = five
                     regions); each slab is a separate ``st.map`` region so
                     dedicated kernels can be launched per face.

Regions are ``((begin, end), ...)`` tuples in interior coordinates, directly
usable as ``st.map(begin=..., end=...)`` arguments.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

Region = Tuple[Tuple[int, int], ...]


def inner_region(shape: Sequence[int], pml_width: int) -> Region:
    return tuple((pml_width, s - pml_width) for s in shape)


def pml_shell(shape: Sequence[int], pml_width: int) -> List[Region]:
    """Disjoint boxes covering the PML shell: 2·ndim slabs (the 'seven
    region' decomposition for 3-D: inner + these 6)."""
    nd = len(shape)
    w = pml_width
    out: List[Region] = []
    for ax in range(nd):
        # axes before `ax` restricted to the inner extent → disjointness
        lo, hi = [], []
        for a in range(nd):
            if a < ax:
                lo.append((w, shape[a] - w))
                hi.append((w, shape[a] - w))
            elif a == ax:
                lo.append((0, w))
                hi.append((shape[a] - w, shape[a]))
            else:
                lo.append((0, shape[a]))
                hi.append((0, shape[a]))
        out.append(tuple(lo))
        out.append(tuple(hi))
    return out


def two_region(shape: Sequence[int], pml_width: int):
    return inner_region(shape, pml_width), pml_shell(shape, pml_width)


def seven_region(shape: Sequence[int], pml_width: int):
    inner = inner_region(shape, pml_width)
    return [inner] + pml_shell(shape, pml_width)


def damping_mask(shape: Sequence[int], pml_width: int,
                 strength: float = 0.1, dtype=jnp.float32) -> jnp.ndarray:
    """Quadratic PML damping coefficient field (zero in the inner region) —
    the 'unified' form used by the distributed backend."""
    nd = len(shape)
    w = max(pml_width, 1)
    total = np.zeros(shape, np.float32)
    for ax in range(nd):
        n = shape[ax]
        x = np.arange(n, dtype=np.float32)
        d = np.maximum(w - x, 0) + np.maximum(x - (n - 1 - w), 0)
        prof = strength * (d / w) ** 2
        shp = [1] * nd
        shp[ax] = n
        total = total + prof.reshape(shp)
    return jnp.asarray(total, dtype)
