"""Adjoint wave propagation: a checkpointed VJP for the fused timeloop.

Inversion workloads (FWI / RTM — what seismic users of high-order stencils
actually run, per Devito) need gradients of a ``steps``-long leapfrog
recursion with respect to the initial grids, the coefficient grids
(velocity model), and the per-scenario scalars.  Naive reverse-mode
through the fused window programs stores every step's carry as a residual
— O(T) wavefields, which is exactly the memory wall Griewank-style
checkpointing exists for.  This module is that scheme over the engine's
own fusion windows:

  forward   — ``jax.custom_vjp`` over the window sequence of
              ``TimeloopEngine`` (the engine's OWN compiled programs, via
              ``engine.window_arrays``): run W windows, snapshotting the
              leapfrog carry (the same full-arrays snapshot structure
              ``train/checkpoint.py`` persists, kept in memory) at every
              ``stride``-th window start.  Checkpoint count ≈ ⌈√T⌉.
  backward  — per checkpoint segment, newest first: REPLAY the segment's
              windows from its checkpoint with the engine's programs
              (bit-exact with the forward run — the same replay primitive
              ``run_resilient``'s resume proves), then walk the segment's
              windows in reverse pulling each cotangent through one
              window's VJP at a time.

The per-window VJP differentiates the always-correct XLA reference
lowering (``lowering.lower_jax_window`` with ``remat=True`` — the oracle
every Pallas kernel is validated against) at the replayed carries.  On
the xla backend that IS the forward program; on the pallas backends the
forward/replay stays on the engine's compiled kernels (``pallas_call``
defines no VJP — and must not be asked for one) while the cotangent
chain runs through the numerically-matching reference window.  On the
distributed backend each window program carries its own VJP
(``distributed.lower_distributed_window(differentiable=True)``): the
cotangent pull is a second shard_map program whose halo exchanges are
the reverse ``ppermute``s of the forward ones (``HaloSpec.transpose``
geometry) with scalar cotangents ``psum``-reduced over the mesh, so the
whole backward pass stays sharded end-to-end.  Masked
(serving) windows differentiate through ``lower_jax_window_masked``,
whose ``where``-based freeze makes the adjoint freeze masked cells and
budget-exhausted scenarios too.  Batched engines differentiate
per-scenario: the reference window is vmapped over the leading scenario
axis exactly like the forward program, so ``(B,)`` scalars and
``(B, ...)`` grids receive per-scenario cotangents.

Peak backward memory: ⌈W/stride⌉ checkpoints + one segment of replayed
carries (≤ stride) + one window of per-step carries (≤ fuse) — with the
default schedule (fuse ≈ ⌈√T⌉, stride thinning the checkpoints back to
≈ ⌈√T⌉ when the caller forces a smaller hook cadence) every term is
O(√T).

``between`` hooks are supported when they are PURE traceable functions
``between(t, arrays) -> arrays`` (e.g. jnp source injection); they fire
at the same window boundaries as ``TimeloopEngine.run`` and are
differentiated as part of the window chain.  Donation is disabled on the
whole path (``timeloop._donate_ok``): a donated window input is dead
after the call and cannot be checkpointed or replayed.

User entry point: ``st.differentiable_timeloop`` in ``core/dsl.py``.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import lowering

__all__ = ["ceil_sqrt", "window_schedule", "checkpoint_stride",
           "differentiable_run", "resilient_grad", "CHECKPOINT_STATS",
           "reset_stats"]

#: trace-time accounting of the most recent forward/backward pass —
#: ``checkpoints`` is the number of carries saved as VJP residuals (the
#: O(√T) bound tests pin), ``replayed_windows``/``vjp_windows`` count the
#: backward pass's recompute work
CHECKPOINT_STATS: Dict[str, int] = {
    "checkpoints": 0, "replayed_windows": 0, "vjp_windows": 0}


def reset_stats() -> None:
    """Zero ``CHECKPOINT_STATS`` (call before tracing a fresh adjoint pass
    so its checkpoint/replay counters start from zero).

    >>> reset_stats()
    >>> CHECKPOINT_STATS["checkpoints"]
    0
    """
    for k in CHECKPOINT_STATS:
        CHECKPOINT_STATS[k] = 0


def ceil_sqrt(n: int) -> int:
    """⌈√n⌉ for n ≥ 0 (exact, no float round-trip)."""
    if n <= 0:
        return 0
    r = math.isqrt(n - 1)
    return r + 1


def window_schedule(steps: int, fuse: int) -> Tuple[Tuple[int, ...],
                                                    Tuple[int, ...]]:
    """(window sizes, window start steps) of a ``steps``-long run driven in
    fusion windows of ``fuse`` — the same decomposition ``run`` executes."""
    sizes: List[int] = []
    starts: List[int] = []
    t = 0
    while t < steps:
        kw = min(fuse, steps - t)
        sizes.append(kw)
        starts.append(t)
        t += kw
    return tuple(sizes), tuple(starts)


def checkpoint_stride(n_windows: int, steps: int) -> int:
    """Checkpoint thinning: snapshot the carry every ``stride``-th window
    start so the stored-checkpoint count stays ≈ ⌈√T⌉ even when the
    window cadence is much finer (fuse_steps=1 → T windows).  With the
    default fuse ≈ ⌈√T⌉ this is 1 (every window start is a checkpoint)."""
    target = max(1, ceil_sqrt(steps))
    return max(1, -(-n_windows // target))


def _zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def _add_trees(a, b):
    return jax.tree.map(jnp.add, a, b)


class _AdjointPlan:
    """Shared prelude of the checkpointed-adjoint drivers: the window
    schedule, the checkpoint thinning, the masked-serving closures, and
    the per-window primal/adjoint callables — everything
    ``differentiable_run`` (in-memory checkpoints) and ``resilient_grad``
    (on-disk checkpoints, restartable) have in common."""

    def __init__(self, engine, steps, fuse_steps, between,
                 domain_mask, step_limits, checkpoint_stride_windows):
        if not engine.differentiable:
            raise ValueError(
                "the checkpointed adjoint requires TimeloopEngine(..., "
                "differentiable=True): an engine that may donate window "
                "inputs cannot be checkpointed or replayed")
        self.engine = engine
        self.between = between
        self.steps = steps = int(steps)
        self.fuse = engine.window_for(
            steps, ceil_sqrt(steps) if fuse_steps is None else fuse_steps)
        self.sizes, self.starts = window_schedule(steps, self.fuse)
        self.W = len(self.sizes)
        self.stride = (int(checkpoint_stride_windows)
                       if checkpoint_stride_windows
                       else checkpoint_stride(self.W, steps))
        self.n_ckpts = -(-self.W // self.stride) if self.W else 0

        self.masked = domain_mask is not None or step_limits is not None
        self.mask = self.limits = None
        if self.masked:
            if not engine.batch \
                    or engine.backend.kind not in ("xla", "distributed"):
                raise ValueError(
                    "domain_mask / step_limits require a batched xla or "
                    "distributed timeloop (the serving path)")
            if domain_mask is None:
                self.mask = jnp.ones((engine.batch,) + engine.interior,
                                     bool)
            else:
                self.mask = jnp.asarray(domain_mask, bool)
            if step_limits is None:
                self.limits = jnp.full((engine.batch,), steps, jnp.int32)
            else:
                self.limits = jnp.asarray(step_limits, jnp.int32)

        self._primal_cache: Dict[int, Callable] = {}
        self._adjoint_cache: Dict[int, Callable] = {}

    # primal/replay: the engine's own compiled programs (bit-exact with a
    # plain engine.run of the same windows)
    def primal_window(self, kw: int) -> Callable:
        fn = self._primal_cache.get(kw)
        if fn is None:
            fn = self.engine.window_arrays(kw, masked=self.masked)
            self._primal_cache[kw] = fn
        return fn

    # adjoint: the XLA reference lowering (remat'd: one carry per step),
    # vmapped over the scenario axis exactly like the engine's programs.
    # The distributed window program carries its own VJP (the shard_map
    # backward program of ``distributed.lower_distributed_window``), so
    # there the adjoint window IS the primal window.
    def adjoint_window(self, kw: int) -> Callable:
        engine = self.engine
        if engine.backend.kind == "distributed":
            return self.primal_window(kw)
        fn = self._adjoint_cache.get(kw)
        if fn is None:
            if self.masked:
                win = lowering.lower_jax_window_masked(
                    engine.kernel, engine.halos, engine.interior,
                    engine.swap, kw, remat=True)
                fn = jax.vmap(win, in_axes=(0, 0, 0, None, 0))
            else:
                win = lowering.lower_jax_window(
                    engine.kernel, engine.halos, engine.interior, None,
                    engine.swap, kw, remat=True)
                fn = jax.vmap(win, in_axes=(0, 0)) if engine.batch else win
            self._adjoint_cache[kw] = fn
        return fn

    def chain(self, i: int, window_fn_for: Callable) -> Callable:
        """Window i as a function of (carry, scalars): the fused window
        program plus the ``between`` hook at its trailing boundary — the
        exact per-window step ``engine.run`` executes."""
        kw, t0 = self.sizes[i], self.starts[i]
        t1 = t0 + kw
        win = window_fn_for(kw)
        between, steps = self.between, self.steps
        masked, mask, limits = self.masked, self.mask, self.limits

        def fn(arrays, scalars):
            if masked:
                out = win(arrays, scalars, mask, jnp.int32(t0), limits)
            else:
                out = win(arrays, scalars)
            if between is not None and t1 < steps:
                out = between(t1, dict(out))
            return dict(out)
        return fn

    def normalize_scalars(self, scalars):
        scal = {}
        for n, v in ({} if scalars is None else scalars).items():
            a = jnp.asarray(v)
            if not jnp.issubdtype(a.dtype, jnp.floating):
                a = a.astype(jnp.float32)
            if self.engine.batch:
                a = jnp.broadcast_to(a, (self.engine.batch,))
            scal[n] = a
        return scal

    def vjp_window(self, i: int, carry, scalars, cot):
        """Pull ``cot`` backward through window i linearized at ``carry``;
        returns (carry cotangent, scalar cotangent contribution)."""
        _, vjp_fn = jax.vjp(self.chain(i, self.adjoint_window),
                            carry, scalars)
        d_carry, d_scal = vjp_fn(dict(cot))
        return dict(d_carry), d_scal


def differentiable_run(engine,
                       steps: int,
                       fuse_steps: Optional[int] = None,
                       between: Optional[Callable] = None,
                       *,
                       domain_mask=None,
                       step_limits=None,
                       checkpoint_stride_windows: Optional[int] = None
                       ) -> Callable:
    """Differentiable counterpart of ``TimeloopEngine.run``.

    Returns a PURE function ``fn(arrays, scalars) -> arrays`` computing
    the same window sequence ``engine.run(arrays, scalars, steps,
    fuse_steps, between)`` executes, but reverse-mode differentiable with
    the O(√T) checkpointed adjoint described in the module docstring.
    Gradients flow to every grid in ``arrays`` (initial wavefields AND
    coefficient grids riding in the carry) and to every float scalar.

    ``fuse_steps=None`` picks the adjoint default ⌈√steps⌉ (the memory-
    optimal single-level schedule) instead of ``run``'s whole-loop
    default; pass it explicitly to pin a ``between``-hook cadence.
    ``domain_mask`` / ``step_limits`` select the masked serving windows
    (batched xla engines only), closed over as non-differentiable
    constants.  ``checkpoint_stride_windows`` overrides the checkpoint
    thinning (testing / memory tuning).

    The engine must be built with ``differentiable=True`` so none of its
    window programs donate their inputs (donated buffers cannot be saved
    as VJP residuals or replayed — ``timeloop._donate_ok``).

    Distributed engines are fully supported: the replay runs the same
    shard_mapped window programs, and the cotangent pull goes through
    each window's own backward shard_map program (reverse ``ppermute``
    halo exchanges — ``fn.spec_T`` geometry) instead of the single-device
    reference window.  Gradients on the swap grids live on the interiors
    (the distributed carry convention keeps grid-halo cells fixed at
    zero, so no cotangent lands on them).

    Example (single device; add ``mesh=`` via ``st.differentiable_timeloop``
    for the sharded version)::

        eng = TimeloopEngine(k.ir, halos, shape, st.xla(), swap=("v", "u"),
                             differentiable=True)
        fn = differentiable_run(eng, steps=100)
        g = jax.grad(lambda a, s: jnp.sum(fn(a, s)["v"] ** 2))(arrays, scal)
    """
    steps = int(steps)
    if steps <= 0:
        def identity(arrays, scalars):
            return dict(arrays)
        identity.schedule = {"windows": (), "starts": (), "stride": 1,
                             "checkpoints": 0}
        return identity

    plan = _AdjointPlan(engine, steps, fuse_steps, between,
                        domain_mask, step_limits, checkpoint_stride_windows)
    W, stride, n_ckpts = plan.W, plan.stride, plan.n_ckpts

    # -- custom VJP --------------------------------------------------------
    @jax.custom_vjp
    def core(arrays, scalars):
        carry = dict(arrays)
        for i in range(W):
            carry = plan.chain(i, plan.primal_window)(carry, scalars)
        return carry

    def core_fwd(arrays, scalars):
        ckpts = []
        carry = dict(arrays)
        for i in range(W):
            if i % stride == 0:
                ckpts.append(carry)
            carry = plan.chain(i, plan.primal_window)(carry, scalars)
        CHECKPOINT_STATS["checkpoints"] = len(ckpts)
        return carry, (tuple(ckpts), scalars)

    def core_bwd(res, cot):
        ckpts, scalars = res
        g_scal = _zeros_like_tree(scalars)
        cot = dict(cot)
        for seg in reversed(range(n_ckpts)):
            first = seg * stride
            last = min(first + stride, W)
            # replay the segment's carries from its checkpoint with the
            # engine's own programs — bit-exact with the forward pass
            carries = [ckpts[seg]]
            for i in range(first, last - 1):
                carries.append(
                    plan.chain(i, plan.primal_window)(carries[-1], scalars))
                CHECKPOINT_STATS["replayed_windows"] += 1
            # pull the cotangent backward one window at a time through the
            # reference adjoint, linearized at the replayed carry
            for i in reversed(range(first, last)):
                cot, gs = plan.vjp_window(i, carries[i - first], scalars,
                                          cot)
                g_scal = _add_trees(g_scal, gs)
                CHECKPOINT_STATS["vjp_windows"] += 1
        return cot, g_scal

    core.defvjp(core_fwd, core_bwd)

    def fn(arrays: Dict[str, jnp.ndarray], scalars=None):
        arrays = {g: jnp.asarray(a) for g, a in arrays.items()}
        return core(arrays, plan.normalize_scalars(scalars))

    fn.schedule = {"windows": plan.sizes, "starts": plan.starts,
                   "stride": stride, "checkpoints": n_ckpts,
                   "fuse": plan.fuse}
    return fn


def resilient_grad(engine,
                   arrays: Dict[str, jnp.ndarray],
                   scalars,
                   steps: int,
                   loss: Callable,
                   *,
                   fuse_steps: Optional[int] = None,
                   between: Optional[Callable] = None,
                   domain_mask=None,
                   step_limits=None,
                   checkpoint_stride_windows: Optional[int] = None,
                   ckpt_dir: str,
                   ckpt_every: int = 1,
                   max_failures: int = 3,
                   injector=None,
                   watchdog=None) -> Dict[str, object]:
    """Fault-tolerant checkpointed gradient: ``value_and_grad`` of
    ``loss(final arrays)`` through the same √T-checkpointed window
    schedule as ``differentiable_run``, driven one restartable unit at a
    time through ``train.fault_tolerance.run_with_restarts``.

    The restartable units are: one fusion window per forward step (the
    √T checkpoints ride in the persisted state), one step seeding the
    cotangent with ``jax.value_and_grad(loss)``, then one checkpoint
    *segment* per backward step (replay ≤ ``stride`` windows, pull the
    cotangent through each in reverse).  A crash anywhere — including
    mid-backward — resumes from the latest on-disk snapshot and yields a
    bit-exact result (deterministic replay, same compiled programs).
    Works on every engine ``differentiable_run`` accepts, including
    distributed engines on a mesh.

    Returns ``{"value", "grad_arrays", "grad_scalars"}``.
    """
    from repro.train import fault_tolerance as _ft

    steps = int(steps)
    init_arrays = {g: jnp.asarray(a) for g, a in arrays.items()}
    if steps <= 0:
        value, cot = jax.value_and_grad(loss)(init_arrays)
        return {"value": value, "grad_arrays": cot,
                "grad_scalars": _zeros_like_tree(dict(scalars or {}))}

    plan = _AdjointPlan(engine, steps, fuse_steps, between,
                        domain_mask, step_limits, checkpoint_stride_windows)
    W, stride, n_ckpts = plan.W, plan.stride, plan.n_ckpts
    scal = plan.normalize_scalars(scalars)

    # constant-treedef restartable state: every phase of the run writes
    # the same pytree structure, so any snapshot restores into any step
    def init_fn():
        zero = _zeros_like_tree(init_arrays)
        return {"carry": dict(init_arrays),
                "ckpts": tuple(dict(zero) for _ in range(n_ckpts)),
                "cot": dict(zero),
                "g_scal": _zeros_like_tree(scal),
                "value": jnp.zeros((), jnp.result_type(float))}

    def step_fn(state, wi):
        state = dict(state)
        if wi < W:                                   # forward window wi
            if wi % stride == 0:
                ckpts = list(state["ckpts"])
                ckpts[wi // stride] = dict(state["carry"])
                state["ckpts"] = tuple(ckpts)
            state["carry"] = plan.chain(wi, plan.primal_window)(
                state["carry"], scal)
        elif wi == W:                                # seed the cotangent
            value, cot = jax.value_and_grad(loss)(state["carry"])
            state["value"] = jnp.asarray(value, state["value"].dtype)
            state["cot"] = dict(cot)
        else:                                        # backward segment
            seg = n_ckpts - 1 - (wi - W - 1)
            first = seg * stride
            last = min(first + stride, W)
            carries = [dict(state["ckpts"][seg])]
            for i in range(first, last - 1):
                carries.append(plan.chain(i, plan.primal_window)(
                    carries[-1], scal))
            cot, g_scal = state["cot"], state["g_scal"]
            for i in reversed(range(first, last)):
                cot, gs = plan.vjp_window(i, carries[i - first], scal, cot)
                g_scal = _add_trees(g_scal, gs)
            state["cot"], state["g_scal"] = cot, g_scal
        return state

    final = _ft.run_with_restarts(
        init_fn=init_fn, step_fn=step_fn, n_steps=W + 1 + n_ckpts,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        max_failures=max_failures, injector=injector, watchdog=watchdog)
    return {"value": final["value"], "grad_arrays": final["cot"],
            "grad_scalars": final["g_scal"]}
