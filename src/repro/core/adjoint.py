"""Adjoint wave propagation: a checkpointed VJP for the fused timeloop.

Inversion workloads (FWI / RTM — what seismic users of high-order stencils
actually run, per Devito) need gradients of a ``steps``-long leapfrog
recursion with respect to the initial grids, the coefficient grids
(velocity model), and the per-scenario scalars.  Naive reverse-mode
through the fused window programs stores every step's carry as a residual
— O(T) wavefields, which is exactly the memory wall Griewank-style
checkpointing exists for.  This module is that scheme over the engine's
own fusion windows:

  forward   — ``jax.custom_vjp`` over the window sequence of
              ``TimeloopEngine`` (the engine's OWN compiled programs, via
              ``engine.window_arrays``): run W windows, snapshotting the
              leapfrog carry (the same full-arrays snapshot structure
              ``train/checkpoint.py`` persists, kept in memory) at every
              ``stride``-th window start.  Checkpoint count ≈ ⌈√T⌉.
  backward  — per checkpoint segment, newest first: REPLAY the segment's
              windows from its checkpoint with the engine's programs
              (bit-exact with the forward run — the same replay primitive
              ``run_resilient``'s resume proves), then walk the segment's
              windows in reverse pulling each cotangent through one
              window's VJP at a time.

The per-window VJP differentiates the always-correct XLA reference
lowering (``lowering.lower_jax_window`` with ``remat=True`` — the oracle
every Pallas kernel is validated against) at the replayed carries.  On
the xla backend that IS the forward program; on the pallas backends the
forward/replay stays on the engine's compiled kernels (``pallas_call``
defines no VJP — and must not be asked for one) while the cotangent
chain runs through the numerically-matching reference window.  Masked
(serving) windows differentiate through ``lower_jax_window_masked``,
whose ``where``-based freeze makes the adjoint freeze masked cells and
budget-exhausted scenarios too.  Batched engines differentiate
per-scenario: the reference window is vmapped over the leading scenario
axis exactly like the forward program, so ``(B,)`` scalars and
``(B, ...)`` grids receive per-scenario cotangents.

Peak backward memory: ⌈W/stride⌉ checkpoints + one segment of replayed
carries (≤ stride) + one window of per-step carries (≤ fuse) — with the
default schedule (fuse ≈ ⌈√T⌉, stride thinning the checkpoints back to
≈ ⌈√T⌉ when the caller forces a smaller hook cadence) every term is
O(√T).

``between`` hooks are supported when they are PURE traceable functions
``between(t, arrays) -> arrays`` (e.g. jnp source injection); they fire
at the same window boundaries as ``TimeloopEngine.run`` and are
differentiated as part of the window chain.  Donation is disabled on the
whole path (``timeloop._donate_ok``): a donated window input is dead
after the call and cannot be checkpointed or replayed.

User entry point: ``st.differentiable_timeloop`` in ``core/dsl.py``.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import lowering

__all__ = ["ceil_sqrt", "window_schedule", "checkpoint_stride",
           "differentiable_run", "CHECKPOINT_STATS", "reset_stats"]

#: trace-time accounting of the most recent forward/backward pass —
#: ``checkpoints`` is the number of carries saved as VJP residuals (the
#: O(√T) bound tests pin), ``replayed_windows``/``vjp_windows`` count the
#: backward pass's recompute work
CHECKPOINT_STATS: Dict[str, int] = {
    "checkpoints": 0, "replayed_windows": 0, "vjp_windows": 0}


def reset_stats() -> None:
    for k in CHECKPOINT_STATS:
        CHECKPOINT_STATS[k] = 0


def ceil_sqrt(n: int) -> int:
    """⌈√n⌉ for n ≥ 0 (exact, no float round-trip)."""
    if n <= 0:
        return 0
    r = math.isqrt(n - 1)
    return r + 1


def window_schedule(steps: int, fuse: int) -> Tuple[Tuple[int, ...],
                                                    Tuple[int, ...]]:
    """(window sizes, window start steps) of a ``steps``-long run driven in
    fusion windows of ``fuse`` — the same decomposition ``run`` executes."""
    sizes: List[int] = []
    starts: List[int] = []
    t = 0
    while t < steps:
        kw = min(fuse, steps - t)
        sizes.append(kw)
        starts.append(t)
        t += kw
    return tuple(sizes), tuple(starts)


def checkpoint_stride(n_windows: int, steps: int) -> int:
    """Checkpoint thinning: snapshot the carry every ``stride``-th window
    start so the stored-checkpoint count stays ≈ ⌈√T⌉ even when the
    window cadence is much finer (fuse_steps=1 → T windows).  With the
    default fuse ≈ ⌈√T⌉ this is 1 (every window start is a checkpoint)."""
    target = max(1, ceil_sqrt(steps))
    return max(1, -(-n_windows // target))


def _zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def _add_trees(a, b):
    return jax.tree.map(jnp.add, a, b)


def differentiable_run(engine,
                       steps: int,
                       fuse_steps: Optional[int] = None,
                       between: Optional[Callable] = None,
                       *,
                       domain_mask=None,
                       step_limits=None,
                       checkpoint_stride_windows: Optional[int] = None
                       ) -> Callable:
    """Differentiable counterpart of ``TimeloopEngine.run``.

    Returns a PURE function ``fn(arrays, scalars) -> arrays`` computing
    the same window sequence ``engine.run(arrays, scalars, steps,
    fuse_steps, between)`` executes, but reverse-mode differentiable with
    the O(√T) checkpointed adjoint described in the module docstring.
    Gradients flow to every grid in ``arrays`` (initial wavefields AND
    coefficient grids riding in the carry) and to every float scalar.

    ``fuse_steps=None`` picks the adjoint default ⌈√steps⌉ (the memory-
    optimal single-level schedule) instead of ``run``'s whole-loop
    default; pass it explicitly to pin a ``between``-hook cadence.
    ``domain_mask`` / ``step_limits`` select the masked serving windows
    (batched xla engines only), closed over as non-differentiable
    constants.  ``checkpoint_stride_windows`` overrides the checkpoint
    thinning (testing / memory tuning).

    The engine must be built with ``differentiable=True`` so none of its
    window programs donate their inputs (donated buffers cannot be saved
    as VJP residuals or replayed — ``timeloop._donate_ok``).
    """
    if engine.backend.kind == "distributed":
        raise NotImplementedError(
            "differentiable timeloop: the distributed fused window is "
            "forward-only (shard_map adjoint not implemented); run the "
            "single-device engine under differentiation")
    if not engine.differentiable:
        raise ValueError(
            "differentiable_run requires TimeloopEngine(..., "
            "differentiable=True): an engine that may donate window "
            "inputs cannot be checkpointed or replayed")
    steps = int(steps)
    if steps <= 0:
        def identity(arrays, scalars):
            return dict(arrays)
        identity.schedule = {"windows": (), "starts": (), "stride": 1,
                             "checkpoints": 0}
        return identity

    fuse = engine.window_for(
        steps, ceil_sqrt(steps) if fuse_steps is None else fuse_steps)
    sizes, starts = window_schedule(steps, fuse)
    W = len(sizes)
    stride = (int(checkpoint_stride_windows) if checkpoint_stride_windows
              else checkpoint_stride(W, steps))
    n_ckpts = -(-W // stride)

    masked = domain_mask is not None or step_limits is not None
    mask = limits = None
    if masked:
        if not engine.batch or engine.backend.kind != "xla":
            raise ValueError(
                "domain_mask / step_limits require a batched xla timeloop "
                "(the serving path)")
        if domain_mask is None:
            mask = jnp.ones((engine.batch,) + engine.interior, bool)
        else:
            mask = jnp.asarray(domain_mask, bool)
        if step_limits is None:
            limits = jnp.full((engine.batch,), steps, jnp.int32)
        else:
            limits = jnp.asarray(step_limits, jnp.int32)

    # -- per-window callables ----------------------------------------------
    # primal/replay: the engine's own compiled programs (bit-exact with a
    # plain engine.run of the same windows)
    _primal_cache: Dict[int, Callable] = {}

    def primal_window(kw: int) -> Callable:
        fn = _primal_cache.get(kw)
        if fn is None:
            fn = engine.window_arrays(kw, masked=masked)
            _primal_cache[kw] = fn
        return fn

    # adjoint: the XLA reference lowering (remat'd: one carry per step),
    # vmapped over the scenario axis exactly like the engine's programs
    _adjoint_cache: Dict[int, Callable] = {}

    def adjoint_window(kw: int) -> Callable:
        fn = _adjoint_cache.get(kw)
        if fn is None:
            if masked:
                win = lowering.lower_jax_window_masked(
                    engine.kernel, engine.halos, engine.interior,
                    engine.swap, kw, remat=True)
                fn = jax.vmap(win, in_axes=(0, 0, 0, None, 0))
            else:
                win = lowering.lower_jax_window(
                    engine.kernel, engine.halos, engine.interior, None,
                    engine.swap, kw, remat=True)
                fn = jax.vmap(win, in_axes=(0, 0)) if engine.batch else win
            _adjoint_cache[kw] = fn
        return fn

    def chain(i: int, window_fn_for: Callable) -> Callable:
        """Window i as a function of (carry, scalars): the fused window
        program plus the ``between`` hook at its trailing boundary — the
        exact per-window step ``engine.run`` executes."""
        kw, t0 = sizes[i], starts[i]
        t1 = t0 + kw
        win = window_fn_for(kw)

        def fn(arrays, scalars):
            if masked:
                out = win(arrays, scalars, mask, jnp.int32(t0), limits)
            else:
                out = win(arrays, scalars)
            if between is not None and t1 < steps:
                out = between(t1, dict(out))
            return dict(out)
        return fn

    # -- custom VJP --------------------------------------------------------
    @jax.custom_vjp
    def core(arrays, scalars):
        carry = dict(arrays)
        for i in range(W):
            carry = chain(i, primal_window)(carry, scalars)
        return carry

    def core_fwd(arrays, scalars):
        ckpts = []
        carry = dict(arrays)
        for i in range(W):
            if i % stride == 0:
                ckpts.append(carry)
            carry = chain(i, primal_window)(carry, scalars)
        CHECKPOINT_STATS["checkpoints"] = len(ckpts)
        return carry, (tuple(ckpts), scalars)

    def core_bwd(res, cot):
        ckpts, scalars = res
        g_scal = _zeros_like_tree(scalars)
        cot = dict(cot)
        for seg in reversed(range(n_ckpts)):
            first = seg * stride
            last = min(first + stride, W)
            # replay the segment's carries from its checkpoint with the
            # engine's own programs — bit-exact with the forward pass
            carries = [ckpts[seg]]
            for i in range(first, last - 1):
                carries.append(chain(i, primal_window)(carries[-1], scalars))
                CHECKPOINT_STATS["replayed_windows"] += 1
            # pull the cotangent backward one window at a time through the
            # reference adjoint, linearized at the replayed carry
            for i in reversed(range(first, last)):
                _, vjp_fn = jax.vjp(chain(i, adjoint_window),
                                    carries[i - first], scalars)
                cot, gs = vjp_fn(cot)
                cot = dict(cot)
                g_scal = _add_trees(g_scal, gs)
                CHECKPOINT_STATS["vjp_windows"] += 1
        return cot, g_scal

    core.defvjp(core_fwd, core_bwd)

    def fn(arrays: Dict[str, jnp.ndarray], scalars=None):
        scalars = {} if scalars is None else scalars
        arrays = {g: jnp.asarray(a) for g, a in arrays.items()}
        scal = {}
        for n, v in scalars.items():
            a = jnp.asarray(v)
            if not jnp.issubdtype(a.dtype, jnp.floating):
                a = a.astype(jnp.float32)
            if engine.batch:
                a = jnp.broadcast_to(a, (engine.batch,))
            scal[n] = a
        return core(arrays, scal)

    fn.schedule = {"windows": sizes, "starts": starts, "stride": stride,
                   "checkpoints": n_ckpts, "fuse": fuse}
    return fn
