"""Static analyses over StencilIR (paper §4.4 "analysis phase").

Infers the domain parameters of paper Table 3 that are "Inferred by kernel
definition": stencil order (halo width per axis), stencil shape
(point / star / box / compact), FLOPs per point, bytes moved per point, and
arithmetic intensity — the quantities the template selector and the roofline
model consume.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from . import ir


@dataclasses.dataclass(frozen=True)
class StencilInfo:
    name: str
    ndim: int
    shape: str                         # 'point' | 'star' | 'box'
    order: int                         # max halo width over axes
    halo: Tuple[int, ...]              # per-axis halo width (max over grids)
    halo_per_grid: Dict[str, Tuple[int, ...]]
    n_taps: int                        # distinct taps
    flops_per_point: int               # adds+muls+divs per output point
    reads_per_point: int               # grid reads per output point
    writes_per_point: int
    input_grids: Tuple[str, ...]
    output_grids: Tuple[str, ...]

    @property
    def bytes_per_point_f32(self) -> int:
        # cold-cache model: each distinct tap is a 4-byte read, each update a
        # 4-byte write (perfect-cache lower bound reads each grid cell once).
        return 4 * (len(self.input_grids) + self.writes_per_point)

    @property
    def arithmetic_intensity_f32(self) -> float:
        return self.flops_per_point / max(self.bytes_per_point_f32, 1)


def _count_flops(e: ir.Expr) -> int:
    if isinstance(e, ir.BinOp):
        return 1 + _count_flops(e.lhs) + _count_flops(e.rhs)
    if isinstance(e, ir.Neg):
        return 1 + _count_flops(e.operand)
    if isinstance(e, ir.Call):
        return 1 + sum(_count_flops(a) for a in e.args)
    return 0


class NotLinearError(ValueError):
    """Raised when a kernel is not an affine combination of taps (the
    Semi-stencil template requires linearity — paper §3 'certain high-order
    stencils')."""


def inline_locals(k: ir.StencilIR):
    """Return the Assign statements with LocalRefs substituted away."""
    env = {}

    def sub(e: ir.Expr) -> ir.Expr:
        if isinstance(e, ir.LocalRef):
            return env[e.name]
        if isinstance(e, ir.BinOp):
            return ir.BinOp(e.op, sub(e.lhs), sub(e.rhs))
        if isinstance(e, ir.Neg):
            return ir.Neg(sub(e.operand))
        if isinstance(e, ir.Call):
            return ir.Call(e.fn, tuple(sub(a) for a in e.args))
        return e

    out = []
    for stmt in k.body:
        if isinstance(stmt, ir.LocalDef):
            env[stmt.name] = sub(stmt.expr)
        else:
            out.append(ir.Assign(stmt.grid, stmt.offsets, sub(stmt.expr)))
    return tuple(out)


def _tapfree(e: ir.Expr) -> bool:
    if isinstance(e, ir.Tap):
        return False
    if isinstance(e, ir.BinOp):
        return _tapfree(e.lhs) and _tapfree(e.rhs)
    if isinstance(e, ir.Neg):
        return _tapfree(e.operand)
    if isinstance(e, ir.Call):
        return all(_tapfree(a) for a in e.args)
    return True


def _center_fieldlike(e: ir.Expr) -> bool:
    """True if every tap in ``e`` is a center tap (all offsets zero) —
    such subtrees act as per-point *coefficient fields* (e.g. vp² in the
    acoustic-ISO update) and are admissible semi-stencil coefficients."""
    if isinstance(e, ir.Tap):
        return not any(e.offsets)
    if isinstance(e, ir.BinOp):
        return _center_fieldlike(e.lhs) and _center_fieldlike(e.rhs)
    if isinstance(e, ir.Neg):
        return _center_fieldlike(e.operand)
    if isinstance(e, ir.Call):
        return all(_center_fieldlike(a) for a in e.args)
    return True


def linearize(e: ir.Expr, allow_center_fields: bool = False):
    """Decompose ``e`` into ``Σ coeff_i * tap_i + const``.

    Returns ``(terms, const)`` where terms maps ``(grid, offsets)`` to a
    coefficient Expr and ``const`` is a coefficient-class Expr.  With
    ``allow_center_fields`` the coefficient class is "center-only taps
    allowed" (evaluated per output point by the backend); otherwise it is
    strictly tap-free.  Raises ``NotLinearError`` for products/divisions of
    non-coefficient tap-bearing subtrees.
    """
    ok_coeff = _center_fieldlike if allow_center_fields else _tapfree
    C0, C1 = ir.Const(0.0), ir.Const(1.0)

    def add(a, b):
        if a == C0:
            return b
        if b == C0:
            return a
        return ir.BinOp("+", a, b)

    def mul(a, b):
        if a == C0 or b == C0:
            return C0
        if a == C1:
            return b
        if b == C1:
            return a
        return ir.BinOp("*", a, b)

    def rec(e):
        if isinstance(e, ir.Tap):
            if allow_center_fields and not any(e.offsets):
                return {}, e  # center tap = coefficient field → const part
            return {(e.grid, e.offsets): C1}, C0
        if ok_coeff(e):
            return {}, e
        if isinstance(e, ir.Neg):
            t, c = rec(e.operand)
            return ({k: ir.Neg(v) for k, v in t.items()}, ir.Neg(c))
        if isinstance(e, ir.BinOp):
            if e.op in ("+", "-"):
                lt, lc = rec(e.lhs)
                rt, rc = rec(e.rhs)
                if e.op == "-":
                    rt = {k: ir.Neg(v) for k, v in rt.items()}
                    rc = ir.Neg(rc)
                out = dict(lt)
                for k, v in rt.items():
                    out[k] = add(out[k], v) if k in out else v
                return out, add(lc, rc)
            if e.op == "*":
                if ok_coeff(e.lhs):
                    t, c = rec(e.rhs)
                    return ({k: mul(e.lhs, v) for k, v in t.items()},
                            mul(e.lhs, c))
                if ok_coeff(e.rhs):
                    t, c = rec(e.lhs)
                    return ({k: mul(e.rhs, v) for k, v in t.items()},
                            mul(e.rhs, c))
                raise NotLinearError("product of tap-bearing expressions")
            if e.op == "/" and ok_coeff(e.rhs):
                t, c = rec(e.lhs)
                return ({k: ir.BinOp("/", v, e.rhs) for k, v in t.items()},
                        ir.BinOp("/", c, e.rhs))
            raise NotLinearError(f"non-linear op {e.op}")
        raise NotLinearError(f"non-linear node {type(e).__name__}")

    return rec(e)


def check_read_after_write(k: ir.StencilIR) -> None:
    """Reject non-center taps of grids written by earlier statements —
    such reads would need a global sync between statements and are not a
    stencil (the map over points must stay parallel)."""
    written = set()
    for stmt in k.body:
        def _taps(e):
            return (x for x in _walk_one(e) if isinstance(x, ir.Tap))
        for t in _taps(stmt.expr):
            if t.grid in written and any(o != 0 for o in t.offsets):
                raise ValueError(
                    f"kernel {k.name}: non-center read of '{t.grid}' after "
                    "it was written in an earlier statement")
        if isinstance(stmt, ir.Assign):
            written.add(stmt.grid)


def _walk_one(e):
    yield e
    if isinstance(e, ir.BinOp):
        yield from _walk_one(e.lhs)
        yield from _walk_one(e.rhs)
    elif isinstance(e, ir.Neg):
        yield from _walk_one(e.operand)
    elif isinstance(e, ir.Call):
        for a in e.args:
            yield from _walk_one(a)


def analyze(k: ir.StencilIR) -> StencilInfo:
    taps = k.taps()
    ndim = k.ndim

    halo_per_grid: Dict[str, list] = {}
    for t in taps:
        h = halo_per_grid.setdefault(t.grid, [0] * ndim)
        for ax, off in enumerate(t.offsets):
            h[ax] = max(h[ax], abs(off))
    halo = tuple(
        max((h[ax] for h in halo_per_grid.values()), default=0)
        for ax in range(ndim)
    )
    order = max(halo) if halo else 0

    # shape classification: star = every tap is on an axis (≤1 nonzero
    # offset component); box otherwise; point if no nonzero offsets.
    distinct = {(t.grid, t.offsets) for t in taps}
    nonzero = [offs for _, offs in distinct if any(o != 0 for o in offs)]
    if not nonzero:
        shape = "point"
    elif all(sum(1 for o in offs if o != 0) <= 1 for offs in nonzero):
        shape = "star"
    else:
        shape = "box"

    flops = 0
    writes = 0
    for stmt in k.body:
        flops += _count_flops(stmt.expr)
        if isinstance(stmt, ir.Assign):
            writes += 1

    return StencilInfo(
        name=k.name,
        ndim=ndim,
        shape=shape,
        order=order,
        halo=halo,
        halo_per_grid={g: tuple(h) for g, h in halo_per_grid.items()},
        n_taps=len(distinct),
        flops_per_point=flops,
        reads_per_point=len(taps),
        writes_per_point=writes,
        input_grids=k.input_grids(),
        output_grids=k.output_grids(),
    )
