"""Auto-tuner over backend templates and their knobs (paper §7 lists this
as future work — implemented here as grid search with measured
time-to-solution, the paper's own metric).

    from repro.core import autotune, dsl as st
    best = autotune.tune(kernel, grids, iters=3)
    st.launch(backend=best.backend)(target)(...)

The search space mirrors Table 6's configuration column: template ×
block (Dx/Dy/Dz) × mem_type × prefetch.  When a ``swap`` pair is given,
the tuner measures fused time-loop execution instead of single
applications and searches the fusion-window size ``fuse_steps`` — and,
for pallas candidates, the in-kernel temporal-blocking depth
``time_block`` — alongside the backend knobs::

    best = autotune.tune(kernel, grids, swap=("v", "u"), steps=32)
    st.launch(backend=best.backend, fuse_steps=best.fuse_steps)(target)(...)

(``best.backend`` carries the winning ``time_block``; candidates whose
k·h halo cannot fit any block are measured as inf and never win.)

Candidates are deduplicated on (backend, fuse_steps) before measuring —
a custom ``space`` overlapping ``fuse_space``/``time_block_space`` pays
for each distinct configuration once.

**Two-stage search** (``top_k``): when the deduplicated space exceeds
``top_k`` candidates, every candidate is first *ranked* by the
analytical cost model (``core/cost_model.py`` — modeled HBM traffic over
a calibrated roofline, no compilation) and only the ``top_k`` cheapest
predicted are measured; candidates the model cannot predict are always
measured.  Distributed candidates are predictable (and hence prunable)
when the tuner is given the mesh (``tune(..., mesh=...)`` — compute at
the local shard shape plus ``HaloSpec`` collective bytes over the link
rate); without a mesh they stay unpredictable and are always measured.
``top_k=None`` recovers the exhaustive search.  ``TuneResult`` records the predictions, the
pruned-candidate count, and the predicted rank of the measured winner
(``rank_error`` — 0 means the model's first choice won), and the disk
cache persists all three so ``benchmarks/check_regression.py`` can guard
model quality.

Results are cached per (kernel, grid geometry, search space, iters,
time-loop configuration) so repeated launches pay once; a custom ``space``
or ``iters`` gets its own cache entry (``clear_cache()`` resets).

The in-process ``_CACHE`` is a read-through layer over an optional
**on-disk JSON cache** (one file per entry, atomic tmp-then-rename
writes), so a warm server process never re-measures configurations a
previous process already tuned.  Disk entries are keyed by (kernel
fingerprint, interior *shape bucket*, search-space/time-loop
configuration, jax backend) with schema versioning — a schema bump or a
different search space simply misses.  Enable it with the
``REPRO_AUTOTUNE_CACHE=<dir>`` environment variable or the
``cache_dir=`` argument to ``tune``.  ``MEASURE_COUNT`` counts actually
measured candidates; a warm-cache hit leaves it untouched (asserted in
CI via ``benchmarks/serve.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import json
import os
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import cost_model as _cost
from . import dsl as st
from . import timeloop as _tl
from .cost_model import kernel_fingerprint  # noqa: F401  (re-export)

_CACHE: Dict = {}

#: bump when the on-disk entry layout changes — old entries then miss
#: (and ``purge_stale`` removes them on first touch of the directory).
#: v2: two-stage search fields (predictions, pruning, rank error) and the
#: cost-model calibration version in the key.
SCHEMA_VERSION = 2

#: environment variable naming the on-disk cache directory
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

#: measured-candidate counter: ``MEASURE_COUNT["measured_candidates"]``
#: increments once per (backend, fuse) configuration actually timed, and
#: ``MEASURE_COUNT["pruned_candidates"]`` once per candidate the cost
#: model pruned from the measured shortlist.  A warm cache (in-process
#: or disk) serves without touching either.
MEASURE_COUNT: collections.Counter = collections.Counter()


def clear_cache() -> None:
    """Drop all memoized tuning results (in-process layer only)."""
    _CACHE.clear()


def reset_measure_count() -> None:
    """Zero the ``MEASURE_COUNT`` telemetry counters (tests use this to
    assert exactly how many candidates a tune() call measured/pruned)."""
    MEASURE_COUNT.clear()


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    """Round each interior extent up to a power of two (floor 8).

    Disk cache entries and the serving layer share this bucketing, so
    mixed request sizes map onto a small set of compiled/tuned
    configurations."""
    return tuple(max(8, 1 << (int(s) - 1).bit_length()) for s in shape)


@dataclasses.dataclass
class TuneResult:
    backend: st.Backend
    seconds: float
    trials: List[Tuple[st.Backend, int, float]]  # (backend, fuse_steps, s)
    fuse_steps: int = 1
    #: every candidate with its modeled cost — (backend, fuse_steps,
    #: predicted seconds | inf (infeasible) | None (unpredictable)).
    #: Empty when no cost model ran (small space, no explicit model).
    predicted: List[Tuple[st.Backend, int, Optional[float]]] = \
        dataclasses.field(default_factory=list)
    #: candidates ranked out of the measured shortlist by the cost model
    pruned_candidates: int = 0
    #: candidates actually timed (== len(trials))
    measured_candidates: int = 0
    #: predicted rank (0-based) of the measured-best candidate — 0 means
    #: the model's first choice also measured fastest; None without a model
    rank_error: Optional[int] = None
    #: the shortlist size this result was tuned with (None = exhaustive)
    top_k: Optional[int] = None


# --------------------------------------------------------------------------
# on-disk cache (read-through under _CACHE)
# --------------------------------------------------------------------------
def _backend_to_json(b) -> Optional[dict]:
    """JSON form of a tunable backend (xla / pallas).  Distributed
    backends carry live mesh references and are not persisted."""
    if b.kind == "xla":
        return {"kind": "xla"}
    if b.kind == "pallas":
        return {"kind": "pallas", "template": b.template,
                "block": list(b.block) if b.block else None,
                "mem_type": b.mem_type, "prefetch": bool(b.prefetch),
                "interpret": bool(b.interpret),
                "time_block": int(b.time_block)}
    return None


def _backend_from_json(d: dict):
    if d["kind"] == "xla":
        return st.xla()
    return st.pallas(template=d["template"],
                     block=tuple(d["block"]) if d["block"] else None,
                     mem_type=d["mem_type"], prefetch=d["prefetch"],
                     interpret=d["interpret"], time_block=d["time_block"])


def _seconds_to_json(s: float):
    return None if not np.isfinite(s) else float(s)


def _pred_to_json(p: Optional[float]):
    """Predictions distinguish inf (infeasible) from None (unpredictable),
    and JSON has no inf — encode it as the string "inf"."""
    if p is None:
        return None
    return "inf" if not np.isfinite(p) else float(p)


def _pred_from_json(p):
    if p is None:
        return None
    return float("inf") if p == "inf" else float(p)


def cache_dir_from_env() -> Optional[str]:
    """Disk-cache directory from ``$REPRO_AUTOTUNE_CACHE`` (the
    ``CACHE_ENV`` variable), or ``None`` when unset/empty — the default
    ``cache_dir`` for ``tune()`` callers that want environment control."""
    return os.environ.get(CACHE_ENV) or None


def _disk_key(kernel, grids, iters, space, swap, steps, fuse_space,
              time_block_space, top_k) -> Tuple[str, dict]:
    """(digest, human-readable key dict) for one disk entry.

    Geometry enters as the *shape bucket* (plus halo order and dtype), so
    every request shape inside a bucket shares the tuned entry — the same
    bucketing the serving layer packs waves by."""
    g0 = next(iter(grids.values()))
    readable = {
        "schema": SCHEMA_VERSION,
        "kernel": kernel.name,
        "fingerprint": kernel_fingerprint(kernel),
        "shape_bucket": list(shape_bucket(g0.shape)),
        "geometry": sorted([n, g.order, str(np.dtype(g.dtype))]
                           for n, g in grids.items()),
        "iters": int(iters),
        "space": repr(_space_key(space)),
        "swap": list(swap) if swap else None,
        "steps": int(steps) if swap else None,
        "fuse_space": [int(f) for f in fuse_space] if swap else None,
        "time_block_space":
            [int(t) for t in time_block_space] if swap else None,
        "top_k": int(top_k) if top_k is not None else None,
        # a recalibrated cost model can change the shortlist, so the
        # calibration version is part of the key
        "calibration": _cost.CALIBRATION_VERSION,
        "jax_backend": jax.default_backend(),
    }
    blob = json.dumps(readable, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:24], readable


def _disk_load(cdir: str, digest: str, readable: dict) -> Optional[TuneResult]:
    path = os.path.join(cdir, f"tune-{digest}.json")
    try:
        with open(path) as f:
            entry = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if entry.get("schema") != SCHEMA_VERSION or entry.get("key") != readable:
        return None  # schema bump or (hash-collision-safe) key mismatch
    try:
        trials = [(_backend_from_json(b), int(fs),
                   float("inf") if s is None else float(s))
                  for b, fs, s in entry["trials"]]
        predicted = [(_backend_from_json(b), int(fs), _pred_from_json(p))
                     for b, fs, p in entry.get("predicted", [])]
        search = entry.get("search", {})
        best = entry["best"]
        rank = search.get("rank_error")
        tk = search.get("top_k")
        return TuneResult(backend=_backend_from_json(best["backend"]),
                          seconds=float("inf") if best["seconds"] is None
                          else float(best["seconds"]),
                          trials=trials, fuse_steps=int(best["fuse_steps"]),
                          predicted=predicted,
                          pruned_candidates=int(
                              search.get("pruned_candidates", 0)),
                          measured_candidates=int(
                              search.get("measured_candidates",
                                         len(trials))),
                          rank_error=int(rank) if rank is not None else None,
                          top_k=int(tk) if tk is not None else None)
    except (KeyError, TypeError, ValueError):
        return None


def _disk_store(cdir: str, digest: str, readable: dict,
                result: TuneResult) -> None:
    bjs = [(_backend_to_json(b), f, s) for b, f, s in result.trials]
    pjs = [(_backend_to_json(b), f, p) for b, f, p in result.predicted]
    if any(b is None for b, _, _ in bjs) \
            or any(b is None for b, _, _ in pjs) \
            or _backend_to_json(result.backend) is None:
        return  # non-serializable backend in the space (e.g. distributed)
    entry = {
        "schema": SCHEMA_VERSION,
        "key": readable,
        "best": {"backend": _backend_to_json(result.backend),
                 "fuse_steps": int(result.fuse_steps),
                 "seconds": _seconds_to_json(result.seconds)},
        "trials": [[b, int(f), _seconds_to_json(s)] for b, f, s in bjs],
        "predicted": [[b, int(f), _pred_to_json(p)] for b, f, p in pjs],
        "search": {"top_k": result.top_k,
                   "pruned_candidates": int(result.pruned_candidates),
                   "measured_candidates": int(result.measured_candidates),
                   "rank_error": result.rank_error},
    }
    os.makedirs(cdir, exist_ok=True)
    # checkpoint.py's tmp-then-rename idiom: readers never see torn writes
    fd, tmp = tempfile.mkstemp(dir=cdir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(entry, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(cdir, f"tune-{digest}.json"))
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


#: directories already swept by ``purge_stale`` this process (one-shot)
_PURGED: set = set()


def purge_stale(cdir: Optional[str] = None) -> int:
    """Remove tune entries written under a different ``SCHEMA_VERSION``
    (or unreadable ones) from ``cdir``.  Without this a schema bump would
    strand every old file on disk forever — a changed key layout also
    changes the digest, so stale files would never even be overwritten.
    ``tune`` runs this once per directory per process on first touch.
    Returns the number of entries removed."""
    cdir = cdir or cache_dir_from_env()
    if not cdir or not os.path.isdir(cdir):
        return 0
    n = 0
    for name in os.listdir(cdir):
        if not (name.startswith("tune-") and name.endswith(".json")):
            continue
        path = os.path.join(cdir, name)
        try:
            with open(path) as f:
                stale = json.load(f).get("schema") != SCHEMA_VERSION
        except (OSError, json.JSONDecodeError):
            stale = True
        if stale:
            try:
                os.unlink(path)
                n += 1
            except OSError:
                pass
    return n


def clear_disk_cache(cdir: Optional[str] = None) -> int:
    """Remove all on-disk entries in ``cdir`` (default: the env-var
    directory).  Returns the number of entries removed."""
    cdir = cdir or cache_dir_from_env()
    if not cdir or not os.path.isdir(cdir):
        return 0
    n = 0
    for name in os.listdir(cdir):
        if name.startswith("tune-") and name.endswith(".json"):
            try:
                os.unlink(os.path.join(cdir, name))
                n += 1
            except OSError:
                pass
    return n


def default_space(ndim: int, interior: Sequence[int]) -> List[st.Backend]:
    """Candidate backends (pruned to blocks that fit the domain)."""
    if ndim == 3:
        blocks = [(8, 8, 128), (8, 16, 128), (16, 8, 128), (8, 8, 256)]
        sblocks = [(16, 8, 128), (32, 8, 128)]
    else:
        blocks = [(8, 128), (16, 128), (8, 256)]
        sblocks = [(16, 128), (32, 128)]
    out: List[st.Backend] = [st.xla()]
    for t in ("gmem", "smem", "f4"):
        for b in blocks:
            out.append(st.pallas(template=t, block=b))
    for t in ("shift", "unroll", "semi"):
        for b, m in itertools.product(sblocks, ("registers", "vmem")):
            if t == "semi" and m == "registers":
                continue
            out.append(st.pallas(template=t, block=b, mem_type=m))
    return out


def _normalize_space(space, ndim, interior, swap, steps, fuse_space,
                     time_block_space=(1,)):
    """Expand the search space into (backend, fuse_steps) candidates.

    With ``swap``, plain backend entries are expanded over ``fuse_space``
    and — for pallas backends — over ``time_block_space`` (the in-kernel
    temporal depth rides on the backend itself).  ``(backend, fuse)``
    tuple entries are taken verbatim.  Duplicates arising from overlap
    between a custom space and the expansion axes are removed before
    measuring, so tuning never times the same configuration twice.
    """
    base = space or default_space(ndim, interior)

    def _norm_fuse(f):
        # the engine's shared window normalization, so the dedup (and the
        # reported fuse_steps) sees the window size that actually runs —
        # e.g. requests ≥ steps collapse to one whole-loop window.  (The
        # overlapped-tiling clamp is mesh-dependent and applied by the
        # engine at measurement time.)
        return _tl.normalize_fuse(max(1, int(f)), steps)

    cands: List[Tuple[st.Backend, int]] = []
    for entry in base:
        if isinstance(entry, tuple):
            b, f = entry
            # without a swap pair only single applications are measured, so
            # a requested window size would be reported but never timed
            cands.append((b, _norm_fuse(f) if swap is not None else 1))
        elif swap is not None:
            backends = [entry]
            if entry.kind == "pallas":
                # expand over the search depths but keep the entry's own
                # (possibly user-pinned) depth in the set — an explicitly
                # requested configuration must be measured, not overwritten
                tbs = dict.fromkeys(
                    [int(getattr(entry, "time_block", 1) or 1)]
                    + [int(tb) for tb in time_block_space])
                backends = [dataclasses.replace(entry, time_block=tb)
                            for tb in tbs]
            for b in backends:
                for f in fuse_space:
                    cands.append((b, _norm_fuse(f)))
        else:
            cands.append((entry, 1))
    # dedup while preserving order
    seen, out = set(), []
    for b, f in cands:
        key = (b.cache_key(), f)
        if key not in seen:
            seen.add(key)
            out.append((b, f))
    return out


def _measure(kernel: st.Kernel, grids: Dict[str, st.grid], backend,
             iters: int, mesh=None) -> float:
    """Median wall time of ``iters`` kernel applications (excludes the
    one-time codegen+compile warmup, like the paper's Kernel column)."""
    gs = {n: g.copy() for n, g in grids.items()}

    @st.target
    def tgt(*args):
        st.map(e=args[0].shape)(kernel)(*args)

    run = st.launch(backend=backend, mesh=mesh)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        res = run(tgt)(*args)
        times.append(res.profile.get("kernel", res.profile["total"]))
    return float(np.median(times))


def _measure_timeloop(kernel: st.Kernel, grids: Dict[str, st.grid],
                      backend, fuse: int, steps: int, swap, iters: int,
                      mesh=None) -> float:
    """Median wall time-to-solution of ``steps`` fused time steps."""
    gs = {n: g.copy() for n, g in grids.items()}

    def tgt(*args):
        return st.timeloop(steps, swap=swap, fuse_steps=fuse)(kernel)(*args)

    run = st.launch(backend=backend, mesh=mesh)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        times.append(run(tgt)(*args).value.seconds)
    return float(np.median(times))


def _space_key(space):
    if space is None:
        return None
    out = []
    for entry in space:
        if isinstance(entry, tuple):
            b, f = entry
            out.append((b.cache_key(), int(f)))
        else:
            out.append((entry.cache_key(), None))
    return tuple(out)


def shortlist_indices(predictions: Sequence[Optional[float]],
                      top_k: int) -> List[int]:
    """Candidate indices the two-stage search measures: the ``top_k``
    cheapest predicted (ties broken by original order — deterministic),
    plus every candidate the model cannot predict (``None``, e.g.
    distributed backends — pruning those would silently drop
    configurations the model knows nothing about).  Original order is
    preserved."""
    ranked = sorted((i for i, p in enumerate(predictions) if p is not None),
                    key=lambda i: (predictions[i], i))
    keep = set(ranked[:max(0, int(top_k))])
    keep.update(i for i, p in enumerate(predictions) if p is None)
    return sorted(keep)


def tune(kernel: st.Kernel, grids: Dict[str, st.grid], iters: int = 3,
         space: Optional[List] = None,
         verbose: bool = False,
         swap: Optional[Tuple[str, str]] = None,
         steps: int = 16,
         fuse_space: Sequence[int] = (1, 4, 16),
         time_block_space: Sequence[int] = (1, 2, 4),
         cache_dir: Optional[str] = None,
         top_k: Optional[int] = 3,
         cost_model: Optional[_cost.CostModel] = None,
         mesh=None) -> TuneResult:
    """Search the backend (and, with ``swap``, the fusion window) —
    two-stage: predict with the analytical cost model, measure a
    shortlist.

    ``space`` entries may be plain backends or ``(backend, fuse_steps)``
    pairs.  Without ``swap`` the tuner measures single kernel applications;
    with ``swap`` it measures ``steps`` fused time-loop steps per candidate
    and searches ``fuse_space`` window sizes for each backend, plus
    ``time_block_space`` in-kernel temporal depths for pallas backends
    (the winner's depth is carried on ``result.backend.time_block``).

    ``top_k`` — when the deduplicated space exceeds ``top_k`` candidates,
    rank all of them with the cost model (``cost_model`` if given, else a
    process-shared calibrated ``cost_model.default_model``) and measure
    only the ``top_k`` cheapest predicted (plus any the model cannot
    predict).  ``top_k=None`` forces the exhaustive search.  Passing an
    explicit ``cost_model`` computes predictions even when nothing is
    pruned — how the benchmarks obtain full predicted-vs-measured data.

    ``mesh`` — the device mesh distributed candidates in ``space`` run
    (and are *priced*) on; threaded into both the cost-model prediction
    and the measurement launches.  Mesh-tuned results stay in the
    in-process cache only (live device references are not persisted).

    ``cache_dir`` (or ``$REPRO_AUTOTUNE_CACHE``) enables the persistent
    on-disk cache: a miss in the in-process layer consults the disk entry
    for this (kernel fingerprint, shape bucket, configuration, top_k,
    calibration version) before predicting or measuring anything, and a
    fresh result is written back atomically.  Disk hits leave
    ``MEASURE_COUNT`` untouched; the first touch of a directory purges
    entries stranded by a ``SCHEMA_VERSION`` bump.
    """
    if top_k is not None and int(top_k) < 1:
        raise ValueError(f"top_k must be >= 1 or None (got {top_k})")
    g0 = next(iter(grids.values()))
    mesh_desc = (tuple(sorted(dict(mesh.shape).items()))
                 if mesh is not None else None)
    key = (kernel.name,
           tuple(sorted((n, g.shape, g.order, str(g.dtype))
                        for n, g in grids.items())),
           int(iters), _space_key(space),
           tuple(swap) if swap else None,
           int(steps) if swap else None,
           tuple(int(f) for f in fuse_space) if swap else None,
           tuple(int(t) for t in time_block_space) if swap else None,
           int(top_k) if top_k is not None else None,
           mesh_desc)
    if key in _CACHE:
        return _CACHE[key]
    cdir = cache_dir or cache_dir_from_env()
    digest = readable = None
    # the disk key carries no mesh descriptor; mesh-tuned results skip the
    # disk layer entirely (they hold live device references anyway)
    use_disk = cdir and mesh is None
    if use_disk:
        if cdir not in _PURGED:
            _PURGED.add(cdir)
            purge_stale(cdir)
        digest, readable = _disk_key(kernel, grids, iters, space, swap,
                                     steps, fuse_space, time_block_space,
                                     top_k)
        result = _disk_load(cdir, digest, readable)
        if result is not None:
            _CACHE[key] = result
            return result
    cands = _normalize_space(space, kernel.info.ndim, g0.shape, swap,
                             steps, fuse_space,
                             time_block_space if swap else (1,))

    # stage 1: rank by predicted cost (geometry + calibrated roofline,
    # no compilation) whenever pruning applies or a model was given
    preds: List[Optional[float]] = []
    if cost_model is not None or (top_k is not None
                                  and len(cands) > int(top_k)):
        cm = cost_model or _cost.default_model(cdir)
        for backend, fuse in cands:
            try:
                p = cm.predict(kernel, grids, backend, fuse, steps, swap,
                               mesh=mesh)
            except Exception:
                p = None
            preds.append(p)
            if verbose and p is not None:
                print(f"  predict {backend} fuse={fuse}: {p:.5f}s",
                      flush=True)
    measure_idx = list(range(len(cands)))
    pruned = 0
    if top_k is not None and len(cands) > int(top_k):
        measure_idx = shortlist_indices(preds, int(top_k))
        pruned = len(cands) - len(measure_idx)
        MEASURE_COUNT["pruned_candidates"] += pruned

    # stage 2: measure the shortlist
    trials = []
    for i in measure_idx:
        backend, fuse = cands[i]
        if swap is None:
            dt = _measure(kernel, grids, backend, iters, mesh=mesh)
        else:
            dt = _measure_timeloop(kernel, grids, backend, fuse, steps,
                                   swap, iters, mesh=mesh)
        MEASURE_COUNT["measured_candidates"] += 1
        trials.append((backend, fuse, dt))
        if verbose:
            print(f"  {backend} fuse={fuse}: {dt:.4f}s", flush=True)
    best = min(trials, key=lambda t: t[2])

    rank_error = None
    predicted = []
    if preds:
        predicted = [(cands[i][0], cands[i][1], preds[i])
                     for i in range(len(cands))]
        order = sorted((i for i, p in enumerate(preds) if p is not None),
                       key=lambda i: (preds[i], i))
        best_key = (best[0].cache_key(), best[1])
        for rank, i in enumerate(order):
            if (cands[i][0].cache_key(), cands[i][1]) == best_key:
                rank_error = rank
                break
    result = TuneResult(backend=best[0], seconds=best[2], trials=trials,
                        fuse_steps=best[1], predicted=predicted,
                        pruned_candidates=pruned,
                        measured_candidates=len(trials),
                        rank_error=rank_error,
                        top_k=int(top_k) if top_k is not None else None)
    _CACHE[key] = result
    if use_disk:
        _disk_store(cdir, digest, readable, result)
    return result
