"""Auto-tuner over backend templates and their knobs (paper §7 lists this
as future work — implemented here as grid search with measured
time-to-solution, the paper's own metric).

    from repro.core import autotune, dsl as st
    best = autotune.tune(kernel, grids, iters=3)
    st.launch(backend=best.backend)(target)(...)

The search space mirrors Table 6's configuration column: template ×
block (Dx/Dy/Dz) × mem_type × prefetch.  When a ``swap`` pair is given,
the tuner measures fused time-loop execution instead of single
applications and searches the fusion-window size ``fuse_steps`` — and,
for pallas candidates, the in-kernel temporal-blocking depth
``time_block`` — alongside the backend knobs::

    best = autotune.tune(kernel, grids, swap=("v", "u"), steps=32)
    st.launch(backend=best.backend, fuse_steps=best.fuse_steps)(target)(...)

(``best.backend`` carries the winning ``time_block``; candidates whose
k·h halo cannot fit any block are measured as inf and never win.)

Candidates are deduplicated on (backend, fuse_steps) before measuring —
a custom ``space`` overlapping ``fuse_space``/``time_block_space`` pays
for each distinct configuration once.

Results are cached per (kernel, grid geometry, search space, iters,
time-loop configuration) so repeated launches pay once; a custom ``space``
or ``iters`` gets its own cache entry (``clear_cache()`` resets).

The in-process ``_CACHE`` is a read-through layer over an optional
**on-disk JSON cache** (one file per entry, atomic tmp-then-rename
writes), so a warm server process never re-measures configurations a
previous process already tuned.  Disk entries are keyed by (kernel
fingerprint, interior *shape bucket*, search-space/time-loop
configuration, jax backend) with schema versioning — a schema bump or a
different search space simply misses.  Enable it with the
``REPRO_AUTOTUNE_CACHE=<dir>`` environment variable or the
``cache_dir=`` argument to ``tune``.  ``MEASURE_COUNT`` counts actually
measured candidates; a warm-cache hit leaves it untouched (asserted in
CI via ``benchmarks/serve.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import json
import os
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import dsl as st
from . import timeloop as _tl

_CACHE: Dict = {}

#: bump when the on-disk entry layout changes — old entries then miss
SCHEMA_VERSION = 1

#: environment variable naming the on-disk cache directory
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

#: measured-candidate counter: ``MEASURE_COUNT["measured_candidates"]``
#: increments once per (backend, fuse) configuration actually timed.
#: A warm cache (in-process or disk) serves without touching it.
MEASURE_COUNT: collections.Counter = collections.Counter()


def clear_cache() -> None:
    """Drop all memoized tuning results (in-process layer only)."""
    _CACHE.clear()


def reset_measure_count() -> None:
    MEASURE_COUNT.clear()


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    """Round each interior extent up to a power of two (floor 8).

    Disk cache entries and the serving layer share this bucketing, so
    mixed request sizes map onto a small set of compiled/tuned
    configurations."""
    return tuple(max(8, 1 << (int(s) - 1).bit_length()) for s in shape)


def kernel_fingerprint(kernel: st.Kernel) -> str:
    """Content hash of a kernel: name + its StencilIR repr.  Editing the
    kernel body changes the fingerprint, invalidating disk entries."""
    text = f"{kernel.name}:{kernel.ir!r}"
    return hashlib.sha256(text.encode()).hexdigest()[:16]


@dataclasses.dataclass
class TuneResult:
    backend: st.Backend
    seconds: float
    trials: List[Tuple[st.Backend, int, float]]  # (backend, fuse_steps, s)
    fuse_steps: int = 1


# --------------------------------------------------------------------------
# on-disk cache (read-through under _CACHE)
# --------------------------------------------------------------------------
def _backend_to_json(b) -> Optional[dict]:
    """JSON form of a tunable backend (xla / pallas).  Distributed
    backends carry live mesh references and are not persisted."""
    if b.kind == "xla":
        return {"kind": "xla"}
    if b.kind == "pallas":
        return {"kind": "pallas", "template": b.template,
                "block": list(b.block) if b.block else None,
                "mem_type": b.mem_type, "prefetch": bool(b.prefetch),
                "interpret": bool(b.interpret),
                "time_block": int(b.time_block)}
    return None


def _backend_from_json(d: dict):
    if d["kind"] == "xla":
        return st.xla()
    return st.pallas(template=d["template"],
                     block=tuple(d["block"]) if d["block"] else None,
                     mem_type=d["mem_type"], prefetch=d["prefetch"],
                     interpret=d["interpret"], time_block=d["time_block"])


def _seconds_to_json(s: float):
    return None if not np.isfinite(s) else float(s)


def cache_dir_from_env() -> Optional[str]:
    return os.environ.get(CACHE_ENV) or None


def _disk_key(kernel, grids, iters, space, swap, steps, fuse_space,
              time_block_space) -> Tuple[str, dict]:
    """(digest, human-readable key dict) for one disk entry.

    Geometry enters as the *shape bucket* (plus halo order and dtype), so
    every request shape inside a bucket shares the tuned entry — the same
    bucketing the serving layer packs waves by."""
    g0 = next(iter(grids.values()))
    readable = {
        "schema": SCHEMA_VERSION,
        "kernel": kernel.name,
        "fingerprint": kernel_fingerprint(kernel),
        "shape_bucket": list(shape_bucket(g0.shape)),
        "geometry": sorted([n, g.order, str(np.dtype(g.dtype))]
                           for n, g in grids.items()),
        "iters": int(iters),
        "space": repr(_space_key(space)),
        "swap": list(swap) if swap else None,
        "steps": int(steps) if swap else None,
        "fuse_space": [int(f) for f in fuse_space] if swap else None,
        "time_block_space":
            [int(t) for t in time_block_space] if swap else None,
        "jax_backend": jax.default_backend(),
    }
    blob = json.dumps(readable, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:24], readable


def _disk_load(cdir: str, digest: str, readable: dict) -> Optional[TuneResult]:
    path = os.path.join(cdir, f"tune-{digest}.json")
    try:
        with open(path) as f:
            entry = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if entry.get("schema") != SCHEMA_VERSION or entry.get("key") != readable:
        return None  # schema bump or (hash-collision-safe) key mismatch
    try:
        trials = [(_backend_from_json(b), int(fs),
                   float("inf") if s is None else float(s))
                  for b, fs, s in entry["trials"]]
        best = entry["best"]
        return TuneResult(backend=_backend_from_json(best["backend"]),
                          seconds=float("inf") if best["seconds"] is None
                          else float(best["seconds"]),
                          trials=trials, fuse_steps=int(best["fuse_steps"]))
    except (KeyError, TypeError, ValueError):
        return None


def _disk_store(cdir: str, digest: str, readable: dict,
                result: TuneResult) -> None:
    bjs = [(_backend_to_json(b), f, s) for b, f, s in result.trials]
    if any(b is None for b, _, _ in bjs) \
            or _backend_to_json(result.backend) is None:
        return  # non-serializable backend in the space (e.g. distributed)
    entry = {
        "schema": SCHEMA_VERSION,
        "key": readable,
        "best": {"backend": _backend_to_json(result.backend),
                 "fuse_steps": int(result.fuse_steps),
                 "seconds": _seconds_to_json(result.seconds)},
        "trials": [[b, int(f), _seconds_to_json(s)] for b, f, s in bjs],
    }
    os.makedirs(cdir, exist_ok=True)
    # checkpoint.py's tmp-then-rename idiom: readers never see torn writes
    fd, tmp = tempfile.mkstemp(dir=cdir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(entry, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(cdir, f"tune-{digest}.json"))
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def clear_disk_cache(cdir: Optional[str] = None) -> int:
    """Remove all on-disk entries in ``cdir`` (default: the env-var
    directory).  Returns the number of entries removed."""
    cdir = cdir or cache_dir_from_env()
    if not cdir or not os.path.isdir(cdir):
        return 0
    n = 0
    for name in os.listdir(cdir):
        if name.startswith("tune-") and name.endswith(".json"):
            try:
                os.unlink(os.path.join(cdir, name))
                n += 1
            except OSError:
                pass
    return n


def default_space(ndim: int, interior: Sequence[int]) -> List[st.Backend]:
    """Candidate backends (pruned to blocks that fit the domain)."""
    if ndim == 3:
        blocks = [(8, 8, 128), (8, 16, 128), (16, 8, 128), (8, 8, 256)]
        sblocks = [(16, 8, 128), (32, 8, 128)]
    else:
        blocks = [(8, 128), (16, 128), (8, 256)]
        sblocks = [(16, 128), (32, 128)]
    out: List[st.Backend] = [st.xla()]
    for t in ("gmem", "smem", "f4"):
        for b in blocks:
            out.append(st.pallas(template=t, block=b))
    for t in ("shift", "unroll", "semi"):
        for b, m in itertools.product(sblocks, ("registers", "vmem")):
            if t == "semi" and m == "registers":
                continue
            out.append(st.pallas(template=t, block=b, mem_type=m))
    return out


def _normalize_space(space, ndim, interior, swap, steps, fuse_space,
                     time_block_space=(1,)):
    """Expand the search space into (backend, fuse_steps) candidates.

    With ``swap``, plain backend entries are expanded over ``fuse_space``
    and — for pallas backends — over ``time_block_space`` (the in-kernel
    temporal depth rides on the backend itself).  ``(backend, fuse)``
    tuple entries are taken verbatim.  Duplicates arising from overlap
    between a custom space and the expansion axes are removed before
    measuring, so tuning never times the same configuration twice.
    """
    base = space or default_space(ndim, interior)

    def _norm_fuse(f):
        # the engine's shared window normalization, so the dedup (and the
        # reported fuse_steps) sees the window size that actually runs —
        # e.g. requests ≥ steps collapse to one whole-loop window.  (The
        # overlapped-tiling clamp is mesh-dependent and applied by the
        # engine at measurement time.)
        return _tl.normalize_fuse(max(1, int(f)), steps)

    cands: List[Tuple[st.Backend, int]] = []
    for entry in base:
        if isinstance(entry, tuple):
            b, f = entry
            # without a swap pair only single applications are measured, so
            # a requested window size would be reported but never timed
            cands.append((b, _norm_fuse(f) if swap is not None else 1))
        elif swap is not None:
            backends = [entry]
            if entry.kind == "pallas":
                # expand over the search depths but keep the entry's own
                # (possibly user-pinned) depth in the set — an explicitly
                # requested configuration must be measured, not overwritten
                tbs = dict.fromkeys(
                    [int(getattr(entry, "time_block", 1) or 1)]
                    + [int(tb) for tb in time_block_space])
                backends = [dataclasses.replace(entry, time_block=tb)
                            for tb in tbs]
            for b in backends:
                for f in fuse_space:
                    cands.append((b, _norm_fuse(f)))
        else:
            cands.append((entry, 1))
    # dedup while preserving order
    seen, out = set(), []
    for b, f in cands:
        key = (b.cache_key(), f)
        if key not in seen:
            seen.add(key)
            out.append((b, f))
    return out


def _measure(kernel: st.Kernel, grids: Dict[str, st.grid], backend,
             iters: int) -> float:
    """Median wall time of ``iters`` kernel applications (excludes the
    one-time codegen+compile warmup, like the paper's Kernel column)."""
    gs = {n: g.copy() for n, g in grids.items()}

    @st.target
    def tgt(*args):
        st.map(e=args[0].shape)(kernel)(*args)

    run = st.launch(backend=backend)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        res = run(tgt)(*args)
        times.append(res.profile.get("kernel", res.profile["total"]))
    return float(np.median(times))


def _measure_timeloop(kernel: st.Kernel, grids: Dict[str, st.grid],
                      backend, fuse: int, steps: int, swap, iters: int) -> float:
    """Median wall time-to-solution of ``steps`` fused time steps."""
    gs = {n: g.copy() for n, g in grids.items()}

    def tgt(*args):
        return st.timeloop(steps, swap=swap, fuse_steps=fuse)(kernel)(*args)

    run = st.launch(backend=backend)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        times.append(run(tgt)(*args).value.seconds)
    return float(np.median(times))


def _space_key(space):
    if space is None:
        return None
    out = []
    for entry in space:
        if isinstance(entry, tuple):
            b, f = entry
            out.append((b.cache_key(), int(f)))
        else:
            out.append((entry.cache_key(), None))
    return tuple(out)


def tune(kernel: st.Kernel, grids: Dict[str, st.grid], iters: int = 3,
         space: Optional[List] = None,
         verbose: bool = False,
         swap: Optional[Tuple[str, str]] = None,
         steps: int = 16,
         fuse_space: Sequence[int] = (1, 4, 16),
         time_block_space: Sequence[int] = (1, 2, 4),
         cache_dir: Optional[str] = None) -> TuneResult:
    """Grid-search the backend (and, with ``swap``, the fusion window).

    ``space`` entries may be plain backends or ``(backend, fuse_steps)``
    pairs.  Without ``swap`` the tuner measures single kernel applications;
    with ``swap`` it measures ``steps`` fused time-loop steps per candidate
    and searches ``fuse_space`` window sizes for each backend, plus
    ``time_block_space`` in-kernel temporal depths for pallas backends
    (the winner's depth is carried on ``result.backend.time_block``).

    ``cache_dir`` (or ``$REPRO_AUTOTUNE_CACHE``) enables the persistent
    on-disk cache: a miss in the in-process layer consults the disk entry
    for this (kernel fingerprint, shape bucket, configuration) before
    measuring anything, and a fresh measurement is written back
    atomically.  Disk hits leave ``MEASURE_COUNT`` untouched.
    """
    g0 = next(iter(grids.values()))
    key = (kernel.name,
           tuple(sorted((n, g.shape, g.order, str(g.dtype))
                        for n, g in grids.items())),
           int(iters), _space_key(space),
           tuple(swap) if swap else None,
           int(steps) if swap else None,
           tuple(int(f) for f in fuse_space) if swap else None,
           tuple(int(t) for t in time_block_space) if swap else None)
    if key in _CACHE:
        return _CACHE[key]
    cdir = cache_dir or cache_dir_from_env()
    digest = readable = None
    if cdir:
        digest, readable = _disk_key(kernel, grids, iters, space, swap,
                                     steps, fuse_space, time_block_space)
        result = _disk_load(cdir, digest, readable)
        if result is not None:
            _CACHE[key] = result
            return result
    cands = _normalize_space(space, kernel.info.ndim, g0.shape, swap,
                             steps, fuse_space,
                             time_block_space if swap else (1,))
    trials = []
    for backend, fuse in cands:
        if swap is None:
            dt = _measure(kernel, grids, backend, iters)
        else:
            dt = _measure_timeloop(kernel, grids, backend, fuse, steps,
                                   swap, iters)
        MEASURE_COUNT["measured_candidates"] += 1
        trials.append((backend, fuse, dt))
        if verbose:
            print(f"  {backend} fuse={fuse}: {dt:.4f}s", flush=True)
    best = min(trials, key=lambda t: t[2])
    result = TuneResult(backend=best[0], seconds=best[2], trials=trials,
                        fuse_steps=best[1])
    _CACHE[key] = result
    if cdir:
        _disk_store(cdir, digest, readable, result)
    return result
