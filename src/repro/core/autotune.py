"""Auto-tuner over backend templates and their knobs (paper §7 lists this
as future work — implemented here as grid search with measured
time-to-solution, the paper's own metric).

    from repro.core import autotune, dsl as st
    best = autotune.tune(kernel, grids, iters=3)
    st.launch(backend=best.backend)(target)(...)

The search space mirrors Table 6's configuration column: template ×
block (Dx/Dy/Dz) × mem_type × prefetch.  When a ``swap`` pair is given,
the tuner measures fused time-loop execution instead of single
applications and searches the fusion-window size ``fuse_steps`` — and,
for pallas candidates, the in-kernel temporal-blocking depth
``time_block`` — alongside the backend knobs::

    best = autotune.tune(kernel, grids, swap=("v", "u"), steps=32)
    st.launch(backend=best.backend, fuse_steps=best.fuse_steps)(target)(...)

(``best.backend`` carries the winning ``time_block``; candidates whose
k·h halo cannot fit any block are measured as inf and never win.)

Candidates are deduplicated on (backend, fuse_steps) before measuring —
a custom ``space`` overlapping ``fuse_space``/``time_block_space`` pays
for each distinct configuration once.

Results are cached per (kernel, grid geometry, search space, iters,
time-loop configuration) so repeated launches pay once; a custom ``space``
or ``iters`` gets its own cache entry (``clear_cache()`` resets).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import dsl as st
from . import timeloop as _tl

_CACHE: Dict = {}


def clear_cache() -> None:
    """Drop all memoized tuning results."""
    _CACHE.clear()


@dataclasses.dataclass
class TuneResult:
    backend: st.Backend
    seconds: float
    trials: List[Tuple[st.Backend, int, float]]  # (backend, fuse_steps, s)
    fuse_steps: int = 1


def default_space(ndim: int, interior: Sequence[int]) -> List[st.Backend]:
    """Candidate backends (pruned to blocks that fit the domain)."""
    if ndim == 3:
        blocks = [(8, 8, 128), (8, 16, 128), (16, 8, 128), (8, 8, 256)]
        sblocks = [(16, 8, 128), (32, 8, 128)]
    else:
        blocks = [(8, 128), (16, 128), (8, 256)]
        sblocks = [(16, 128), (32, 128)]
    out: List[st.Backend] = [st.xla()]
    for t in ("gmem", "smem", "f4"):
        for b in blocks:
            out.append(st.pallas(template=t, block=b))
    for t in ("shift", "unroll", "semi"):
        for b, m in itertools.product(sblocks, ("registers", "vmem")):
            if t == "semi" and m == "registers":
                continue
            out.append(st.pallas(template=t, block=b, mem_type=m))
    return out


def _normalize_space(space, ndim, interior, swap, steps, fuse_space,
                     time_block_space=(1,)):
    """Expand the search space into (backend, fuse_steps) candidates.

    With ``swap``, plain backend entries are expanded over ``fuse_space``
    and — for pallas backends — over ``time_block_space`` (the in-kernel
    temporal depth rides on the backend itself).  ``(backend, fuse)``
    tuple entries are taken verbatim.  Duplicates arising from overlap
    between a custom space and the expansion axes are removed before
    measuring, so tuning never times the same configuration twice.
    """
    base = space or default_space(ndim, interior)

    def _norm_fuse(f):
        # the engine's shared window normalization, so the dedup (and the
        # reported fuse_steps) sees the window size that actually runs —
        # e.g. requests ≥ steps collapse to one whole-loop window.  (The
        # overlapped-tiling clamp is mesh-dependent and applied by the
        # engine at measurement time.)
        return _tl.normalize_fuse(max(1, int(f)), steps)

    cands: List[Tuple[st.Backend, int]] = []
    for entry in base:
        if isinstance(entry, tuple):
            b, f = entry
            # without a swap pair only single applications are measured, so
            # a requested window size would be reported but never timed
            cands.append((b, _norm_fuse(f) if swap is not None else 1))
        elif swap is not None:
            backends = [entry]
            if entry.kind == "pallas":
                # expand over the search depths but keep the entry's own
                # (possibly user-pinned) depth in the set — an explicitly
                # requested configuration must be measured, not overwritten
                tbs = dict.fromkeys(
                    [int(getattr(entry, "time_block", 1) or 1)]
                    + [int(tb) for tb in time_block_space])
                backends = [dataclasses.replace(entry, time_block=tb)
                            for tb in tbs]
            for b in backends:
                for f in fuse_space:
                    cands.append((b, _norm_fuse(f)))
        else:
            cands.append((entry, 1))
    # dedup while preserving order
    seen, out = set(), []
    for b, f in cands:
        key = (b.cache_key(), f)
        if key not in seen:
            seen.add(key)
            out.append((b, f))
    return out


def _measure(kernel: st.Kernel, grids: Dict[str, st.grid], backend,
             iters: int) -> float:
    """Median wall time of ``iters`` kernel applications (excludes the
    one-time codegen+compile warmup, like the paper's Kernel column)."""
    gs = {n: g.copy() for n, g in grids.items()}

    @st.target
    def tgt(*args):
        st.map(e=args[0].shape)(kernel)(*args)

    run = st.launch(backend=backend)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        res = run(tgt)(*args)
        times.append(res.profile.get("kernel", res.profile["total"]))
    return float(np.median(times))


def _measure_timeloop(kernel: st.Kernel, grids: Dict[str, st.grid],
                      backend, fuse: int, steps: int, swap, iters: int) -> float:
    """Median wall time-to-solution of ``steps`` fused time steps."""
    gs = {n: g.copy() for n, g in grids.items()}

    def tgt(*args):
        return st.timeloop(steps, swap=swap, fuse_steps=fuse)(kernel)(*args)

    run = st.launch(backend=backend)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        times.append(run(tgt)(*args).value.seconds)
    return float(np.median(times))


def _space_key(space):
    if space is None:
        return None
    out = []
    for entry in space:
        if isinstance(entry, tuple):
            b, f = entry
            out.append((b.cache_key(), int(f)))
        else:
            out.append((entry.cache_key(), None))
    return tuple(out)


def tune(kernel: st.Kernel, grids: Dict[str, st.grid], iters: int = 3,
         space: Optional[List] = None,
         verbose: bool = False,
         swap: Optional[Tuple[str, str]] = None,
         steps: int = 16,
         fuse_space: Sequence[int] = (1, 4, 16),
         time_block_space: Sequence[int] = (1, 2, 4)) -> TuneResult:
    """Grid-search the backend (and, with ``swap``, the fusion window).

    ``space`` entries may be plain backends or ``(backend, fuse_steps)``
    pairs.  Without ``swap`` the tuner measures single kernel applications;
    with ``swap`` it measures ``steps`` fused time-loop steps per candidate
    and searches ``fuse_space`` window sizes for each backend, plus
    ``time_block_space`` in-kernel temporal depths for pallas backends
    (the winner's depth is carried on ``result.backend.time_block``).
    """
    g0 = next(iter(grids.values()))
    key = (kernel.name,
           tuple(sorted((n, g.shape, g.order, str(g.dtype))
                        for n, g in grids.items())),
           int(iters), _space_key(space),
           tuple(swap) if swap else None,
           int(steps) if swap else None,
           tuple(int(f) for f in fuse_space) if swap else None,
           tuple(int(t) for t in time_block_space) if swap else None)
    if key in _CACHE:
        return _CACHE[key]
    cands = _normalize_space(space, kernel.info.ndim, g0.shape, swap,
                             steps, fuse_space,
                             time_block_space if swap else (1,))
    trials = []
    for backend, fuse in cands:
        if swap is None:
            dt = _measure(kernel, grids, backend, iters)
        else:
            dt = _measure_timeloop(kernel, grids, backend, fuse, steps,
                                   swap, iters)
        trials.append((backend, fuse, dt))
        if verbose:
            print(f"  {backend} fuse={fuse}: {dt:.4f}s", flush=True)
    best = min(trials, key=lambda t: t[2])
    result = TuneResult(backend=best[0], seconds=best[2], trials=trials,
                        fuse_steps=best[1])
    _CACHE[key] = result
    return result
