"""Auto-tuner over backend templates and their knobs (paper §7 lists this
as future work — implemented here as grid search with measured
time-to-solution, the paper's own metric).

    from repro.core import autotune, dsl as st
    best = autotune.tune(kernel, grids, iters=3)
    st.launch(backend=best.backend)(target)(...)

The search space mirrors Table 6's configuration column: template ×
block (Dx/Dy/Dz) × mem_type × prefetch.  Results are cached per
(kernel, interior shape, dtype) so repeated launches pay once.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import dsl as st

_CACHE: Dict = {}


@dataclasses.dataclass
class TuneResult:
    backend: st.Backend
    seconds: float
    trials: List[Tuple[st.Backend, float]]


def default_space(ndim: int, interior: Sequence[int]) -> List[st.Backend]:
    """Candidate backends (pruned to blocks that fit the domain)."""
    if ndim == 3:
        blocks = [(8, 8, 128), (8, 16, 128), (16, 8, 128), (8, 8, 256)]
        sblocks = [(16, 8, 128), (32, 8, 128)]
    else:
        blocks = [(8, 128), (16, 128), (8, 256)]
        sblocks = [(16, 128), (32, 128)]
    out: List[st.Backend] = [st.xla()]
    for t in ("gmem", "smem", "f4"):
        for b in blocks:
            out.append(st.pallas(template=t, block=b))
    for t in ("shift", "unroll", "semi"):
        for b, m in itertools.product(sblocks, ("registers", "vmem")):
            if t == "semi" and m == "registers":
                continue
            out.append(st.pallas(template=t, block=b, mem_type=m))
    return out


def _measure(kernel: st.Kernel, grids: Dict[str, st.grid], backend,
             iters: int) -> float:
    """Median wall time of ``iters`` kernel applications (excludes the
    one-time codegen+compile warmup, like the paper's Kernel column)."""
    gs = {n: g.copy() for n, g in grids.items()}

    @st.target
    def tgt(*args):
        st.map(e=args[0].shape)(kernel)(*args)

    run = st.launch(backend=backend)
    args = tuple(gs.values())
    try:
        run(tgt)(*args)                      # warmup: codegen + compile
    except Exception:
        return float("inf")
    times = []
    for _ in range(iters):
        res = run(tgt)(*args)
        times.append(res.profile.get("kernel", res.profile["total"]))
    return float(np.median(times))


def tune(kernel: st.Kernel, grids: Dict[str, st.grid], iters: int = 3,
         space: Optional[List[st.Backend]] = None,
         verbose: bool = False) -> TuneResult:
    g0 = next(iter(grids.values()))
    key = (kernel.name, g0.shape, str(g0.dtype))
    if key in _CACHE:
        return _CACHE[key]
    space = space or default_space(kernel.info.ndim, g0.shape)
    trials = []
    for backend in space:
        dt = _measure(kernel, grids, backend, iters)
        trials.append((backend, dt))
        if verbose:
            print(f"  {backend}: {dt:.4f}s", flush=True)
    best = min(trials, key=lambda t: t[1])
    result = TuneResult(backend=best[0], seconds=best[1], trials=trials)
    _CACHE[key] = result
    return result
