"""Fused time-loop execution engine: single-program time stepping.

The per-step path (``st.map`` inside a Python loop) pays one compiled call,
one host↔device sync and one dict-of-arrays repack per time step — and on
the Pallas path a full ``jnp.pad`` halo repack per grid per step.  Devito
and the Cerebras stencil work both show that fusing the time dimension into
the generated program is where stencil throughput lives; this module is
that fusion for all three backends:

  xla          — ``steps`` applications + leapfrog buffer rotation run in
                 one jitted ``lax.fori_loop`` program with donated buffers
                 (``lowering.lower_jax_window``).
  pallas       — lowering is split into a one-time layout stage (grids →
                 persistent block-padded layout, ONE ``jnp.pad`` per grid
                 per fusion window) and a per-invocation kernel stage
                 executed inside the fused loop (``codegen.plan_pallas``);
                 outputs are written in-place in padded layout and the grid
                 halo is passed through, so no repacking happens between
                 steps.  With ``time_block=k`` on the backend, one kernel
                 invocation advances k leapfrog steps entirely in VMEM
                 (expanded k·h halos, in-kernel temporal blocking) — the
                 fusion window is decomposed into ⌊kw/k⌋ k-step invocations
                 plus a remainder of single steps, so any window length
                 runs exactly — ``fuse_steps`` (the host-sync / between-
                 hook cadence) is honored as requested, never rounded to
                 the temporal depth.  The k-step invocations double-buffer
                 the swap pair: outputs land in spare padded buffers that
                 ping-pong with the read buffers between invocations.
                 Modeled HBM traffic per window is accumulated in
                 ``codegen.TRAFFIC_COUNT`` alongside ``PAD_COUNT``.
  distributed  — the ENTIRE fusion window runs as ONE jitted shard_map'd
                 program (``distributed.lower_distributed_window``): a
                 ``lax.fori_loop`` over depth-k exchange groups
                 (k = ``time_steps`` × inner ``time_block``) plus an
                 unrolled remainder group, each group = one k·h-wide halo
                 exchange + k kernel applications on shrinking regions +
                 the leapfrog swap, with the deep-interior pre-pass issued
                 before the ppermutes resolve so communication overlaps
                 compute across steps.  ``fuse_steps`` stays the host-sync
                 / between-hook cadence; ``time_steps``/``time_block`` set
                 only the exchange *depth* within the window.  Batched
                 scenarios ride a leading unsharded axis inside the same
                 program.

The host syncs only at fusion-window boundaries; an optional ``between``
hook runs there (e.g. acoustic source injection).

With ``batch=B`` the engine carries a leading *scenario* dimension: one
compiled program advances B independent grid-sets (distinct initial
conditions, coefficient grids, and scalar parameters) per step.  The
per-window program is ``jax.vmap``-ped over the leading axis — on the
pallas path XLA's batching rule turns the scenario axis into an extra
leading grid dimension of the same ``pallas_call`` (the batched operand
layout), so the kernel stage stays one program.  Scalars may be python
floats (broadcast) or ``(B,)`` arrays (per-scenario).  The batched xla
path additionally supports *masked* windows for shape-bucketed serving
(``lowering.lower_jax_window_masked``): a per-scenario spatial mask
freezes cells outside a request's true sub-domain and a per-scenario
step budget freezes finished scenarios, both exactly.

This module is DSL-agnostic: it works on dicts of jnp arrays.  The user
API is ``st.timeloop(...)`` / ``st.launch(..., fuse_steps=K)`` in
``core/dsl.py``; the array-level wrapper is
``repro.kernels.stencil.ops.stencil_timeloop``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ir as _ir
from . import lowering


def window_parts(kw: int, k_inner: int) -> list:
    """Decompose a fusion window that is not a multiple of the temporal
    depth: the largest ``k_inner`` multiple (depth active) plus the
    remainder — an indivisible window must degrade only its remainder to
    depth 1, never the whole window.  Both backends now decompose
    *inside* one program (pallas: ⌊kw/k⌋ k-step invocations + single
    steps; distributed: fori_loop groups + an unrolled remainder group,
    the same split expressed by ``halo.HaloSpec.group_depths``); this
    helper states the invariant and backs the group accounting."""
    if k_inner > 1 and kw > k_inner and kw % k_inner:
        return [kw - kw % k_inner, kw % k_inner]
    return [kw]


def backend_time_block(backend) -> int:
    """Effective in-kernel temporal depth of a backend: the knob rides on
    the backend itself for pallas, on the pallas ``inner`` for distributed
    wrappers, and is 1 everywhere else.  The single reader shared by the
    engine and the distributed lowering — they must agree on the depth or
    the window decomposition and the exchange width disagree."""
    if getattr(backend, "kind", "") == "distributed":
        backend = getattr(backend, "inner", None)
    return int(getattr(backend, "time_block", 1) or 1)


def normalize_fuse(fuse_steps: Optional[int], steps: int,
                   max_fuse: Optional[int] = None) -> int:
    """Fusion-window normalization shared by the engine and the autotuner
    (both must agree on the window that actually runs).

    Clamp the request to the loop length and the overlapped-tiling bound
    (``max_fuse``) — the hard constraints — and nothing else.
    ``fuse_steps`` is the host-sync / ``between``-hook cadence (source
    injection, diagnostics), which the engine honors *exactly*: in-kernel
    temporal blocking never alters the window, because every window
    decomposes into ⌊kw/k⌋ k-step invocations plus a single-step
    remainder (in-program on the pallas path, via ``window_parts`` on the
    distributed path).  Rounding a window to the temporal depth would
    silently move hook firings — changing physics, not just speed."""
    steps = int(steps)
    if steps <= 0:
        return 1
    if fuse_steps is None:
        fuse = steps
    else:
        fuse = int(fuse_steps)
        if fuse < 1:
            raise ValueError("fuse_steps must be >= 1")
    fuse = min(fuse, steps)
    if max_fuse is not None:
        fuse = min(fuse, max_fuse)
    return fuse


def normalize_swap(kernel: _ir.StencilIR,
                   swap: Optional[Tuple[str, str]]) -> Optional[Tuple[str, str]]:
    """Validate and orient a swap pair as (written, other)."""
    if swap is None:
        return None
    a, b = swap
    params = set(kernel.grid_params)
    for g in (a, b):
        if g not in params:
            raise ValueError(f"swap grid '{g}' is not a kernel parameter")
    outs = set(kernel.output_grids())
    wr = [g for g in (a, b) if g in outs]
    if len(wr) != 1:
        raise ValueError(
            f"swap pair {swap} must contain exactly one output grid "
            f"(outputs: {sorted(outs)})")
    written = wr[0]
    other = b if written == a else a
    return (written, other)


def _rotate(arrays: Dict[str, jnp.ndarray], swap) -> Dict[str, jnp.ndarray]:
    out = dict(arrays)
    out[swap[0]], out[swap[1]] = out[swap[1]], out[swap[0]]
    return out


def _under_trace() -> bool:
    """True while some jax transformation is tracing — i.e. the engine is
    being driven from inside someone else's program (the adjoint replay,
    a user jit around ``run``)."""
    try:
        return not jax.core.trace_state_clean()
    except AttributeError:      # moved/renamed across jax versions
        return False


def _donate_ok(differentiable: bool = False) -> bool:
    """Whether fused-window programs may donate their input buffers.

    Donation is gated by backend (CPU jit does not implement it — warns
    and copies) AND by differentiation: a donated window input is dead
    after the call, so it cannot be saved as a VJP residual or replayed
    from a checkpoint — the backward pass would read freed buffers.  Both
    an explicit ``differentiable=True`` engine flag and trace detection
    (the window being built while another transform is tracing, as the
    adjoint's forward/replay passes do) disable donation."""
    if differentiable or _under_trace():
        return False
    return jax.default_backend() in ("tpu", "gpu")


class TimeloopEngine:
    """Backend-specific fused window programs for one (kernel, geometry).

    ``run(arrays, scalars, steps, fuse_steps, between)`` executes ``steps``
    applications of the kernel (+ buffer rotation when ``swap`` is set) in
    fusion windows of ``fuse_steps``, syncing with the host only at window
    boundaries.  Returns the final arrays dict (same naming convention as
    the per-step path: after each step the ``swap`` names trade buffers).
    """

    def __init__(self, kernel: _ir.StencilIR,
                 halos: Mapping[str, Tuple[int, ...]],
                 interior_shape: Tuple[int, ...],
                 backend,
                 swap: Optional[Tuple[str, str]] = None,
                 mesh=None,
                 profile_cb: Optional[Callable[[str, float], None]] = None,
                 batch: int = 0,
                 differentiable: bool = False):
        self.kernel = kernel
        self.halos = {g: tuple(h) for g, h in halos.items()}
        self.interior = tuple(interior_shape)
        self.backend = backend
        self.swap = normalize_swap(kernel, swap)
        self.mesh = mesh
        self.differentiable = bool(differentiable)
        self.batch = int(batch)
        if self.batch < 0:
            raise ValueError("batch must be >= 0 (0 = unbatched)")
        self._profile_cb = profile_cb
        self._windows: Dict[Tuple[int, bool], Callable] = {}
        self._plan = self._plan1 = None
        self.time_block = 1
        if backend.kind == "pallas":
            from repro.kernels.stencil import codegen as _codegen
            # (plan construction time is charged to "codegen" by the caller)
            self._plan = _codegen.plan_pallas(
                kernel, self.halos, self.interior, backend, swap=self.swap)
            self.time_block = self._plan.time_block
            if self.time_block > 1:
                # single-step plan for the fusion-window remainder
                # (kw mod time_block) — shares the padded geometry so the
                # same layout buffers feed both kernels
                be1 = dataclasses.replace(backend, time_block=1,
                                          block=self._plan.B)
                self._plan1 = _codegen.plan_pallas(
                    kernel, self.halos, self.interior, be1, swap=self.swap)
            else:
                self._plan1 = self._plan
        elif backend.kind not in ("xla", "distributed"):
            raise ValueError(f"timeloop: unsupported backend {backend.kind}")
        if backend.kind == "distributed":
            if self.swap is None:
                raise ValueError("distributed timeloop requires swap=(a, b)")
            self.time_block = backend_time_block(backend)
        # fuse_steps no longer needs an overlapped-tiling clamp: the fused
        # window decomposes into exchange groups of the backend's temporal
        # depth, and only the *depth* (time_steps × time_block) must fit
        # k·h ≤ local extent — validated by HaloSpec at lowering time
        self.max_fuse: Optional[int] = None

    # -- helpers -----------------------------------------------------------
    def _add(self, phase: str, dt: float) -> None:
        if self._profile_cb is not None:
            self._profile_cb(phase, dt)

    def _window(self, kw: int, masked: bool = False) -> Callable:
        """Compiled fused program for a window of ``kw`` steps.

        ``masked=True`` (batched xla only) selects the serving variant with
        per-scenario spatial masks and step budgets."""
        fn = self._windows.get((kw, masked))
        if fn is not None:
            return fn
        t0 = time.perf_counter()
        donate = (0,) if _donate_ok(self.differentiable) else ()
        if masked:
            if self.backend.kind not in ("xla", "distributed") \
                    or not self.batch:
                raise ValueError(
                    "masked windows require a batched xla or distributed "
                    "timeloop")
            if self.backend.kind == "distributed":
                from . import distributed as _dist
                fn = _dist.lower_distributed_window(
                    self.kernel, self.interior, self.backend, self.mesh,
                    self.swap, kw, batch=self.batch,
                    differentiable=self.differentiable, masked=True)
            else:
                win = lowering.lower_jax_window_masked(
                    self.kernel, self.halos, self.interior, self.swap, kw)
                # mask and limit are per-scenario; start is window-global
                fn = jax.jit(jax.vmap(win, in_axes=(0, 0, 0, None, 0)),
                             donate_argnums=donate)
        elif self.backend.kind == "xla":
            win = lowering.lower_jax_window(
                self.kernel, self.halos, self.interior, None, self.swap, kw)
            if self.batch:
                win = jax.vmap(win, in_axes=(0, 0))
            fn = jax.jit(win, donate_argnums=donate)
        elif self.backend.kind == "pallas":
            plan, plan1, swap = self._plan, self._plan1, self.swap
            k = self.time_block
            m, r = divmod(kw, k)

            def win(padded, scalars):
                from jax import lax

                def body_k(_, carry):
                    # double-buffered k-step invocation: outputs land in
                    # the spare buffers (the kernel must not write the
                    # buffers whose k·h windows other blocks still read),
                    # and the buffers just read become the next
                    # invocation's spares.  A k-step invocation leaves
                    # buffer↔name bindings untouched; k leapfrog rotations
                    # net to k mod 2, applied to the output AND spare
                    # names together so every output name keeps a
                    # destination carrying its own ring (padding + halo).
                    p, sp = carry
                    out = plan.step(p, scalars, spares=sp)
                    new_sp = {g: p[g] for g in plan.step_out_grids}
                    if swap and k % 2:
                        out = _rotate(out, swap)
                        new_sp = _rotate(new_sp, swap)
                    return out, new_sp

                def body_1(_, p):
                    out = plan1.step(p, scalars)
                    return _rotate(out, swap) if swap else out

                p = dict(padded)
                if m and k > 1:
                    p, _ = lax.fori_loop(0, m, body_k,
                                         (p, plan.make_spares(p)))
                elif m:
                    p = lax.fori_loop(0, m, body_1, p)
                if r:
                    p = lax.fori_loop(0, r, body_1, p)
                return p
            if self.batch:
                # XLA's batching rule lifts the scenario axis into an extra
                # leading grid dimension of the same pallas_call — one
                # program still advances all B scenarios per invocation
                win = jax.vmap(win, in_axes=(0, 0))
            fn = jax.jit(win, donate_argnums=donate)
        else:  # distributed: the whole window is ONE shard_map'd program
            from . import distributed as _dist
            fn = _dist.lower_distributed_window(
                self.kernel, self.interior, self.backend, self.mesh,
                self.swap, kw, batch=self.batch,
                differentiable=self.differentiable)
        self._add("comp", time.perf_counter() - t0)
        self._windows[(kw, masked)] = fn
        return fn

    def window_for(self, steps: int, fuse_steps: Optional[int] = None) -> int:
        """The fusion-window size that actually runs for this request
        (see ``normalize_fuse``).  Idempotent, so callers may report the
        result and pass it back to ``run``."""
        return normalize_fuse(fuse_steps, steps, self.max_fuse)

    def window_arrays(self, kw: int, masked: bool = False) -> Callable:
        """PURE arrays-level callable for one fused window of ``kw`` steps:
        ``fn(arrays, scalars) -> arrays`` (masked:
        ``fn(arrays, scalars, mask, start, limits) -> arrays``), with the
        same carry convention as ``run`` — on the pallas path the padded
        layout round-trip and the host-side leapfrog name parity are folded
        in, so the returned function maps full (grid-halo'd) arrays to full
        arrays on every backend.

        This is the carry-capture surface of the adjoint engine
        (``core/adjoint.py``): the forward pass of the timeloop VJP runs
        these callables to snapshot checkpointed carries and the backward
        pass replays them bit-exactly from those checkpoints (the same
        replay primitive ``run_resilient`` relies on).  Unlike
        ``_run_window``, no wall-clock profiling, host syncs, or modeled-
        traffic counters fire here — the function must be traceable inside
        another transform."""
        if masked or self.backend.kind in ("xla", "distributed"):
            return self._window(kw, masked=masked)
        plan, swap, batch = self._plan, self.swap, self.batch
        win = self._window(kw)

        def fn(arrays, scal):
            padded = (jax.vmap(plan.to_padded)(arrays) if batch
                      else plan.to_padded(arrays))
            padded = win(padded, scal)
            if swap and kw % 2:
                arrays = _rotate(arrays, swap)
            return (jax.vmap(plan.from_padded)(padded, arrays) if batch
                    else plan.from_padded(padded, arrays))
        return fn

    # -- driver ------------------------------------------------------------
    def run(self, arrays: Dict[str, jnp.ndarray],
            scalars: Mapping[str, jnp.ndarray],
            steps: int,
            fuse_steps: Optional[int] = None,
            between: Optional[Callable] = None,
            *,
            domain_mask: Optional[jnp.ndarray] = None,
            step_limits=None) -> Dict[str, jnp.ndarray]:
        """Advance the grids ``steps`` applications and return the final
        buffers.

        Args:
            arrays: grid name → halo-padded buffer (leading scenario axis
                of ``self.batch`` when batched).  Not mutated.
            scalars: scalar-param name → value; floats broadcast, ``(B,)``
                arrays stay per-scenario under batching.
            steps: total kernel applications (with the leapfrog swap
                rotation between them).
            fuse_steps: fusion-window size; ``None`` fuses the whole loop.
                Clamped via ``window_for``.
            between: optional host hook ``between(t, arrays) -> arrays``
                invoked at every window boundary.
            domain_mask: per-scenario boolean interior mask — ``False``
                cells hold their values (serving: frozen regions).
                Requires a batched xla or distributed engine.
            step_limits: per-scenario step counts; scenario ``b`` stops
                advancing after ``step_limits[b]`` applications.

        Returns a NEW dict of final buffers (same keys/shapes as
        ``arrays``); window programs are compiled once per (window, mask)
        signature and cached on the engine."""
        fuse = self.window_for(steps, fuse_steps)
        arrays = dict(arrays)
        if self.batch:
            for g, a in arrays.items():
                if a.ndim != len(self.interior) + 1 \
                        or a.shape[0] != self.batch:
                    raise ValueError(
                        f"batched timeloop: grid '{g}' must carry a leading "
                        f"scenario axis of {self.batch} (got {a.shape})")
            # python floats broadcast; (B,) arrays stay per-scenario
            scal = {n: jnp.broadcast_to(jnp.asarray(v, jnp.float32),
                                        (self.batch,))
                    for n, v in scalars.items()}
        else:
            scal = {n: jnp.asarray(v, jnp.float32)
                    for n, v in scalars.items()}
        masked = domain_mask is not None or step_limits is not None
        mask = limits = None
        if masked:
            if not self.batch \
                    or self.backend.kind not in ("xla", "distributed"):
                raise ValueError(
                    "domain_mask / step_limits require a batched xla or "
                    "distributed timeloop (the serving path)")
            if domain_mask is None:
                mask = jnp.ones((self.batch,) + self.interior, bool)
            else:
                mask = jnp.asarray(domain_mask, bool)
                if mask.shape != (self.batch,) + self.interior:
                    raise ValueError(
                        f"domain_mask must have shape "
                        f"{(self.batch,) + self.interior} (got {mask.shape})")
            if step_limits is None:
                limits = jnp.full((self.batch,), steps, jnp.int32)
            else:
                limits = jnp.asarray(step_limits, jnp.int32)
                if limits.shape != (self.batch,):
                    raise ValueError(
                        f"step_limits must have shape ({self.batch},) "
                        f"(got {limits.shape})")
        t = 0
        while t < steps:
            kw = min(fuse, steps - t)
            t0 = time.perf_counter()
            if masked:
                arrays = self._window(kw, masked=True)(
                    arrays, scal, mask, jnp.int32(t), limits)
            else:
                arrays = self._run_window(arrays, scal, kw)
            jax.block_until_ready(arrays)
            self._add("kernel", time.perf_counter() - t0)
            t += kw
            if between is not None and t < steps:
                arrays = between(t, arrays) or arrays
        return arrays

    def _run_window(self, arrays, scal, kw):
        if self.backend.kind == "xla":
            return self._window(kw)(arrays, scal)
        if self.backend.kind == "pallas":
            plan = self._plan
            t0 = time.perf_counter()
            if self.batch:
                # vmapped layout stage: still ONE pad per grid per window
                # (eager vmap pads all B scenarios in a single batched op)
                padded = jax.vmap(plan.to_padded)(arrays)
            else:
                padded = plan.to_padded(arrays)     # ONE pad/grid/window
            self._add("layout", time.perf_counter() - t0)
            plan.count_window(kw, batch=max(1, self.batch))  # modeled HBM
            padded = self._window(kw)(padded, scal)
            # the device program rotated padded buffers kw times; apply the
            # same parity to the full host arrays so halos travel with
            # their buffers, then write the padded interiors back
            if self.swap and kw % 2:
                arrays = _rotate(arrays, self.swap)
            if self.batch:
                return jax.vmap(plan.from_padded)(padded, arrays)
            return plan.from_padded(padded, arrays)
        # distributed: one program advances the whole window (exchange
        # groups + remainder + every leapfrog rotation happen in-program)
        return self._window(kw)(arrays, scal)


def run_timeloop(kernel: _ir.StencilIR,
                 arrays: Dict[str, jnp.ndarray],
                 scalars: Mapping[str, jnp.ndarray],
                 steps: int,
                 *,
                 halos: Mapping[str, Tuple[int, ...]],
                 interior_shape: Tuple[int, ...],
                 backend,
                 swap: Optional[Tuple[str, str]] = None,
                 fuse_steps: Optional[int] = None,
                 between: Optional[Callable] = None,
                 mesh=None,
                 batch: int = 0) -> Dict[str, jnp.ndarray]:
    """One-shot convenience wrapper (builds a fresh engine)."""
    eng = TimeloopEngine(kernel, halos, interior_shape, backend,
                         swap=swap, mesh=mesh, batch=batch)
    return eng.run(dict(arrays), scalars, steps, fuse_steps, between)


def run_resilient(engine: TimeloopEngine,
                  arrays: Dict[str, jnp.ndarray],
                  scalars: Mapping[str, jnp.ndarray],
                  steps: int,
                  fuse_steps: Optional[int] = None,
                  between: Optional[Callable] = None,
                  *,
                  ckpt_dir: str,
                  ckpt_every: int = 1,
                  max_failures: int = 3,
                  injector=None,
                  watchdog=None,
                  loss: Optional[Callable] = None) -> Dict[str, jnp.ndarray]:
    """Fault-tolerant timeloop driver: checkpoint/restore of the leapfrog
    carry through ``train.checkpoint`` + ``train.fault_tolerance``.

    The simulation advances one fusion window per restartable step; every
    ``ckpt_every`` windows the full arrays dict (the leapfrog carry — both
    swap buffers plus coefficient grids) is snapshotted atomically to
    ``ckpt_dir``.  On a failure (or a fresh process pointed at the same
    directory) the run restores the latest snapshot and resumes from that
    window boundary.  Replay is deterministic — each window re-executes
    the identical compiled program on the identical carry — so a resumed
    run is bit-exact with an uninterrupted one (pinned in
    tests/test_resilience.py).  ``between`` fires at the same window
    boundaries as ``engine.run`` (a window is never re-split), so source
    injection timing survives restarts too.  Works for every backend the
    engine supports, including the distributed fused window on a mesh.

    ``loss`` (a pure scalar function of the final arrays) switches the
    driver to a fault-tolerant *gradient* run: the forward sweep AND the
    checkpointed backward sweep both advance one restartable unit at a
    time and resume from the latest snapshot after a failure — see
    ``adjoint.resilient_grad``.  Returns that function's result dict
    (``value`` / ``grad_arrays`` / ``grad_scalars``) instead of the final
    arrays; requires ``TimeloopEngine(..., differentiable=True)``.
    """
    from repro.train import fault_tolerance as _ft

    if loss is not None:
        from . import adjoint as _adj
        return _adj.resilient_grad(
            engine, arrays, scalars, steps, loss, fuse_steps=fuse_steps,
            between=between, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            max_failures=max_failures, injector=injector,
            watchdog=watchdog)

    fuse = engine.window_for(steps, fuse_steps)
    n_windows = -(-steps // fuse) if steps > 0 else 0
    init_arrays = {g: jnp.asarray(a) for g, a in arrays.items()}

    def init_fn():
        return dict(init_arrays)

    def step_fn(state, wi):
        t0 = wi * fuse
        kw = min(fuse, steps - t0)
        out = engine.run(dict(state), scalars, kw, kw)
        t1 = t0 + kw
        if between is not None and t1 < steps:
            out = between(t1, out) or out
        return out

    if n_windows == 0:
        return dict(init_arrays)
    return _ft.run_with_restarts(
        init_fn=init_fn, step_fn=step_fn, n_steps=n_windows,
        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        max_failures=max_failures, injector=injector, watchdog=watchdog)
