"""Analytical cost model for autotune candidates: predict, don't measure.

The autotuner's search space (template × block × fuse_steps × time_block)
already runs to dozens of compiled candidates per kernel, and every
planned extension — streaming templates, meshes, batch sizes — multiplies
it.  Devito ships an analytical performance model next to its autotuner
for exactly this reason; this module is ours.

A candidate's cost is modeled as roofline time over its HBM traffic plus
a per-dispatch overhead::

    seconds ≈ (steps · bytes_per_step + windows · bytes_per_window)
              / bytes_per_s  +  windows · overhead_s

where the traffic terms are **deterministic geometry**, not measurements:

  * pallas candidates — ``PallasPlan`` is constructed (never compiled)
    and charged ``plan.hbm_bytes_per_step()`` for the steady-state kernel
    stage plus ``plan.layout_bytes_per_window()`` for the per-window
    to_padded/make_spares/from_padded costs.  A plan that raises
    ``ValueError`` (infeasible k·h, misaligned f4 block, …) predicts
    ``inf`` — the same value measuring it would produce.
  * xla candidates — a short probe window is AOT-lowered once per
    (kernel, geometry) via ``lowering.lower_jax_window`` and the HLO-text
    walk (``launch/hlo_analysis.op_stats``) charges its trip-count-aware
    HBM bytes; the result is memoized so one compile covers every
    ``fuse_steps`` expansion of the candidate.

``(bytes_per_s, overhead_s)`` is a per-(execution class, dtype) **rate**
calibrated once per process from a tiny star2d1r probe timeloop — a fully
fused run pins the bandwidth term, a fuse=1 run of the same loop isolates
the per-window overhead — and persisted next to the autotune disk cache
(``roofline-v{CALIBRATION_VERSION}-{jax_backend}.json``) so warm
processes never re-probe.  ``CostModel(calibrate=False)`` skips probing
and uses ``DEFAULT_RATES`` (deterministic — what the tests rank with).

The model's job is *ranking*, not absolute prediction: the calibrated
~10³ bandwidth gap between compiled XLA and interpret-mode pallas, and
the monotone window-overhead term, are what ``autotune.tune``'s two-stage
search prunes with.  ``benchmarks/timeloop.py`` records predicted-vs-
measured rank quality and CI guards it (``check_regression.py``).

Distributed candidates are priced when the caller supplies the mesh
(``predict(..., mesh=...)``): the per-shard compute term reuses the XLA
byte accounting at the *local* shard shape (the fused sharded window runs
its sub-steps through the same ``lower_jax`` regions — a Pallas ``inner``
only changes exchange depth), and the collective term charges
``halo.HaloSpec.window_collective_bytes`` — the exact per-window ppermute
traffic of ``distributed.lower_distributed_window`` — against the
``"link"`` rate plus one link overhead per exchange group.  The link
rate is *measured* when the caller's mesh is a real ``jax.sharding.Mesh``
with ≥ 2 devices: a tiny ppermute ring probe at two message sizes solves
(bytes_per_s, overhead_s) for that device count, keyed
``link@{ndev}/{dtype}`` and persisted in the roofline JSON beside the
compute rates.  A plain ``{axis: size}`` mapping (shape known, devices
unknown) or a single-device mesh falls back to the fixed
``DEFAULT_RATES["link"]``.  Without a mesh the prediction stays ``None``
(geometry unknown → the tuner measures).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import tempfile
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from . import dsl as st
from . import halo as _halo
from . import lowering as _lowering
from . import timeloop as _tl
from repro.launch import hlo_analysis as _hlo

__all__ = ["CALIBRATION_VERSION", "Rate", "DEFAULT_RATES", "CostModel",
           "default_model", "reset_default_models", "exec_key",
           "kernel_fingerprint"]

#: bump when the prediction formula or the probe protocol changes —
#: persisted calibrations (and disk tune entries, which key on this via
#: ``autotune._disk_key``) then miss and re-derive
#: (v2: measured ``link@{ndev}`` rates join the roofline JSON)
CALIBRATION_VERSION = 2

#: fori_loop length of the AOT-lowered window used for XLA byte
#: accounting: ≥ 2 keeps the loop a genuine ``while`` in optimized HLO
#: (a trip-count-1 loop may be simplified away), and per-window constants
#: average out over the probe steps
_XLA_PROBE_STEPS = 4

#: probe geometry per execution class: small enough that calibration is
#: ~a second, large enough that the fused run is traffic- not
#: overhead-dominated
_PROBE = {
    "xla": {"shape": (48, 48), "steps": 12},
    "pallas": {"shape": (32, 32), "steps": 8},
    "pallas_interpret": {"shape": (24, 32), "steps": 6},
}


def kernel_fingerprint(kernel: st.Kernel) -> str:
    """Content hash of a kernel: name + its StencilIR repr.  Editing the
    kernel body changes the fingerprint, invalidating disk entries."""
    text = f"{kernel.name}:{kernel.ir!r}"
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def exec_key(backend) -> Optional[str]:
    """Calibration class of a backend — which measured rate applies.
    ``None`` means the backend has no single-device execution class:
    distributed cost is mesh-dependent, so ``predict`` prices it only
    when given the mesh (compute at the local shape over the xla rate +
    collectives over the ``"link"`` rate) and the tuner measures it
    otherwise."""
    kind = getattr(backend, "kind", None)
    if kind == "xla":
        return "xla"
    if kind == "pallas":
        return "pallas_interpret" if backend.interpret else "pallas"
    return None


@dataclasses.dataclass(frozen=True)
class Rate:
    """Calibrated execution rate for one (execution class, dtype):
    effective bandwidth against the model's own byte accounting, plus a
    fixed per-dispatch overhead charged once per fusion window."""
    bytes_per_s: float
    overhead_s: float


#: fallback rates when calibration is off or the probe fails.  Absolute
#: values are deliberately coarse; the ranking-relevant property is the
#: ~10³ bandwidth gap between compiled paths and interpret-mode pallas.
DEFAULT_RATES: Dict[str, Rate] = {
    "xla": Rate(bytes_per_s=2e9, overhead_s=2e-4),
    "pallas": Rate(bytes_per_s=2e9, overhead_s=2e-4),
    "pallas_interpret": Rate(bytes_per_s=2e6, overhead_s=2e-3),
    # inter-shard halo-exchange traffic: bandwidth per ppermute byte plus
    # a fixed latency per exchange *group* (one exchange round).  This is
    # the NO-MESH fallback only — ``rate_for("link", dtype, mesh=...)``
    # measures the real rate with a ppermute ring probe whenever the mesh
    # carries ≥ 2 actual devices.  The ranking-relevant property of the
    # fallback is that link bytes are slower and rounds far more expensive
    # than local HBM, so deeper time skewing (fewer, wider exchanges)
    # predicts cheaper.
    "link": Rate(bytes_per_s=1e9, overhead_s=5e-4),
}


def _rate_key(key: str, dtype) -> str:
    return f"{key}/{np.dtype(dtype).name}"


def _probeable_mesh(mesh):
    """The mesh, iff it is a real ``jax.sharding.Mesh`` whose device set a
    ppermute probe can actually exercise (≥ 2 devices); else ``None``.
    Plain ``{axis: size}`` shape mappings price geometry but name no
    devices, so the link rate stays the fixed fallback for them."""
    devices = getattr(mesh, "devices", None)
    if devices is None:
        return None
    return mesh if np.asarray(devices).size >= 2 else None


class CostModel:
    """Deterministic candidate-cost predictor (see module docstring).

    ``cache_dir`` — persist/load calibrated rates next to the autotune
    disk cache.  ``calibrate=False`` — never probe; use ``rates`` then
    ``DEFAULT_RATES`` (fully deterministic, the testing configuration).
    ``rates`` — pre-seeded {"class/dtype": Rate} overrides.
    """

    def __init__(self, cache_dir: Optional[str] = None,
                 calibrate: bool = True,
                 rates: Optional[Dict[str, Rate]] = None):
        self.cache_dir = cache_dir
        self.calibrate = calibrate
        self._rates: Dict[str, Rate] = dict(rates or {})
        self._bytes_memo: Dict = {}
        if cache_dir:
            self._load_rates()

    # -- rates -------------------------------------------------------------
    def rate_for(self, key: str, dtype, mesh=None) -> Rate:
        """Calibrated (or default) rate for one execution class × dtype.
        First use per process probes (when ``calibrate``) and persists.

        ``mesh`` applies to the ``"link"`` class only: a real device mesh
        (≥ 2 devices) switches to the *measured* inter-shard rate for that
        device count — probed once with a ppermute ring and persisted as
        ``link@{ndev}/{dtype}`` — while a shape-only mapping, a 1-device
        mesh, or no mesh keeps the fixed ``DEFAULT_RATES["link"]``."""
        probe_mesh = _probeable_mesh(mesh) if key == "link" else None
        rk = (_rate_key(f"link@{np.asarray(probe_mesh.devices).size}", dtype)
              if probe_mesh is not None else _rate_key(key, dtype))
        r = self._rates.get(rk)
        if r is None:
            if key == "link" and probe_mesh is None:
                # nothing to measure against — fixed fallback, not cached
                # to disk so a later real-mesh call still probes
                return DEFAULT_RATES["link"]
            if self.calibrate:
                try:
                    r = (self._probe_link(dtype, probe_mesh)
                         if key == "link" else self._probe(key, dtype))
                except Exception:
                    r = DEFAULT_RATES[key]
            else:
                r = DEFAULT_RATES[key]
            self._rates[rk] = r
            if self.cache_dir:
                self._store_rates()
        return r

    def _probe(self, key: str, dtype) -> Rate:
        """Measure one Rate from a tiny star2d1r timeloop.

        A fully fused run (one window) and a fuse=1 run (one window per
        step) of the same ``steps``-step loop differ only in window
        count, so::

            overhead_s  = (t_split − t_full) / (steps − 1)
            bytes_per_s = (steps·bytes_per_step + bytes_per_window)
                          / (t_full − overhead_s)

        with the byte terms taken from this model's own accounting — the
        calibration is consistent with prediction by construction."""
        from . import suite as _suite
        cfg = _PROBE[key]
        shape, steps = cfg["shape"], cfg["steps"]
        if key == "xla":
            backend = st.xla()
        else:
            backend = st.pallas(template="gmem",
                                interpret=(key == "pallas_interpret"))
        k = _suite.get_kernel("star2d1r")
        swap = _suite.swap_pair("star2d1r")

        def run_once(fuse: int) -> float:
            grids = {g: st.grid(dtype, shape, k.info.order).randomize(i)
                     for i, g in enumerate(k.ir.grid_params)}

            def tgt(*args):
                return st.timeloop(steps, swap=swap,
                                   fuse_steps=fuse)(k)(*args)

            run = st.launch(backend=backend)
            args = tuple(grids.values())
            run(tgt)(*args)                  # warmup: codegen + compile
            return min(run(tgt)(*args).value.seconds for _ in range(2))

        t_full = run_once(steps)
        t_split = run_once(1)
        overhead = max((t_split - t_full) / max(steps - 1, 1), 1e-8)
        halos = {g: (k.info.order,) * k.info.ndim for g in k.ir.grid_params}
        per_step, per_window = self.step_bytes(k, halos, tuple(shape),
                                               backend, swap, dtype)
        bw = (steps * per_step + per_window) / max(t_full - overhead, 1e-9)
        return Rate(bytes_per_s=max(bw, 1.0), overhead_s=overhead)

    #: ppermute-probe protocol: per-shard message elements at the two
    #: sizes, and rounds per timed call (amortizes dispatch the same way
    #: the fused exchange schedule does)
    _LINK_PROBE = {"small": 1 << 10, "big": 1 << 16, "rounds": 8}

    def _probe_link(self, dtype, mesh) -> Rate:
        """Measure the inter-shard ``"link"`` Rate on a real device mesh.

        All mesh devices form a 1-D ppermute ring (the exact collective
        ``distributed.lower_distributed_window`` issues per halo
        exchange); one jitted shard_map runs ``rounds`` ring shifts over a
        per-shard message.  Timing that program at two message sizes
        gives two equations in the roofline's two unknowns::

            t(bytes) = bytes / bytes_per_s + overhead_s

        so ``bytes_per_s = Δbytes/Δt`` and ``overhead_s`` falls out of the
        small-message time.  Bytes are per shard per round — the same
        accounting ``HaloSpec.window_collective_bytes`` charges."""
        import time
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        devs = np.asarray(mesh.devices).reshape(-1)
        n = devs.size
        ring = Mesh(devs, ("ring",))
        perm = [(i, (i + 1) % n) for i in range(n)]
        rounds = self._LINK_PROBE["rounds"]

        def ring_fn(x):
            def body(_, y):
                return jax.lax.ppermute(y, "ring", perm)
            return jax.lax.fori_loop(0, rounds, body, x)

        def per_round_seconds(elems: int) -> float:
            x = jnp.zeros((n * elems,), dtype)
            f = jax.jit(shard_map(ring_fn, mesh=ring,
                                  in_specs=P("ring"), out_specs=P("ring")))
            f(x).block_until_ready()          # compile + warm the path
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                f(x).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            return best / rounds

        itemsize = np.dtype(dtype).itemsize
        small, big = self._LINK_PROBE["small"], self._LINK_PROBE["big"]
        t_small = per_round_seconds(small)
        t_big = per_round_seconds(big)
        d_bytes = (big - small) * itemsize
        bw = d_bytes / max(t_big - t_small, 1e-12)
        overhead = max(t_small - small * itemsize / bw, 1e-8)
        return Rate(bytes_per_s=max(bw, 1.0), overhead_s=overhead)

    # -- calibration persistence (next to the autotune disk cache) ---------
    def _cal_path(self) -> str:
        return os.path.join(
            self.cache_dir,
            f"roofline-v{CALIBRATION_VERSION}-{jax.default_backend()}.json")

    def _load_rates(self) -> None:
        try:
            with open(self._cal_path()) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            return
        if data.get("version") != CALIBRATION_VERSION:
            return
        for rk, r in data.get("rates", {}).items():
            try:
                self._rates.setdefault(
                    rk, Rate(float(r["bytes_per_s"]), float(r["overhead_s"])))
            except (KeyError, TypeError, ValueError):
                continue

    def _store_rates(self) -> None:
        entry = {
            "version": CALIBRATION_VERSION,
            "jax_backend": jax.default_backend(),
            "rates": {rk: {"bytes_per_s": r.bytes_per_s,
                           "overhead_s": r.overhead_s}
                      for rk, r in self._rates.items()},
        }
        os.makedirs(self.cache_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(entry, f, indent=1, sort_keys=True)
            os.replace(tmp, self._cal_path())
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    # -- traffic -----------------------------------------------------------
    def step_bytes(self, kernel: st.Kernel, halos, interior, backend,
                   swap, dtype) -> Optional[Tuple[float, float]]:
        """(bytes per time step, bytes per fusion window) for a candidate,
        from geometry alone — no compilation on the pallas path, one
        memoized AOT lowering per (kernel, geometry) on the xla path.
        ``(inf, 0)`` marks an infeasible pallas plan; ``None`` a backend
        the model cannot account (the tuner measures those)."""
        key = exec_key(backend)
        if key is None:
            return None
        memo_key = (kernel_fingerprint(kernel),
                    tuple(sorted((g, tuple(h)) for g, h in halos.items())),
                    tuple(interior), backend.cache_key(),
                    tuple(swap) if swap else None, np.dtype(dtype).name)
        hit = self._bytes_memo.get(memo_key)
        if hit is not None:
            return hit
        itemsize = np.dtype(dtype).itemsize
        if key == "xla":
            out = (self._xla_step_bytes(kernel, halos, interior, swap,
                                        dtype), 0.0)
        else:
            from repro.kernels.stencil import codegen as _codegen
            try:
                plan = _codegen.plan_pallas(kernel.ir, dict(halos),
                                            tuple(interior), backend,
                                            swap=tuple(swap) if swap
                                            else None)
            except ValueError:
                out = (float("inf"), 0.0)
            else:
                out = (plan.hbm_bytes_per_step(itemsize),
                       plan.layout_bytes_per_window(itemsize))
        self._bytes_memo[memo_key] = out
        return out

    def _xla_step_bytes(self, kernel, halos, interior, swap, dtype) -> float:
        """Per-step HBM bytes of the fused xla window: AOT-lower a short
        ``lower_jax_window`` probe and walk its optimized HLO.  The probe
        length divides out, so one compile serves every fuse_steps."""
        try:
            steps = _XLA_PROBE_STEPS
            win = _lowering.lower_jax_window(
                kernel.ir, dict(halos), tuple(interior), None,
                tuple(swap) if swap else None, steps)
            abstract = {
                g: jax.ShapeDtypeStruct(
                    tuple(interior[ax] + 2 * halos[g][ax]
                          for ax in range(len(interior))), dtype)
                for g in kernel.ir.grid_params}
            scal = {n: jax.ShapeDtypeStruct((), np.float32)
                    for n, _dt in kernel.ir.scalar_params}
            compiled = jax.jit(win).lower(abstract, scal).compile()
            stats = _hlo.op_stats(compiled.as_text())
            return float(stats.hbm_bytes) / steps
        except Exception:
            # mirror the tuner's measured semantics: a candidate that
            # cannot lower/compile costs inf and never wins
            return float("inf")

    # -- prediction --------------------------------------------------------
    def predict(self, kernel: st.Kernel, grids: Dict[str, st.grid],
                backend, fuse: int, steps: int,
                swap: Optional[Tuple[str, str]],
                mesh=None) -> Optional[float]:
        """Predicted seconds for the quantity the tuner measures: ``steps``
        fused time steps (or one application when ``swap`` is None).
        ``None`` — unpredictable backend; ``inf`` — infeasible candidate.
        ``mesh`` (a ``jax.sharding.Mesh`` or an {axis: size} mapping)
        makes distributed candidates predictable; without it they stay
        ``None`` and are always measured.
        """
        if getattr(backend, "kind", None) == "distributed":
            return self._predict_distributed(kernel, grids, backend, fuse,
                                             steps, swap, mesh)
        key = exec_key(backend)
        if key is None:
            return None
        g0 = next(iter(grids.values()))
        interior = tuple(g0.shape)
        batch = max(1, int(g0.batch or 1))
        halos = {n: g.halo for n, g in grids.items()}
        sb = self.step_bytes(kernel, halos, interior, backend, swap,
                             g0.dtype)
        if sb is None:
            return None
        per_step, per_window = sb
        if not math.isfinite(per_step):
            return float("inf")
        rate = self.rate_for(key, g0.dtype)
        if swap is None:
            return batch * per_step / rate.bytes_per_s + rate.overhead_s
        steps = max(1, int(steps))
        windows = -(-steps // max(1, int(fuse)))
        traffic = batch * (steps * per_step + windows * per_window)
        return traffic / rate.bytes_per_s + windows * rate.overhead_s

    def _predict_distributed(self, kernel, grids, backend, fuse, steps,
                             swap, mesh) -> Optional[float]:
        """Price a distributed candidate on a known mesh: per-shard compute
        bytes at the local shape over the xla rate (the fused window's
        sub-steps run through ``lower_jax``) + per-window ``HaloSpec``
        collective bytes over the link rate + one link overhead per
        exchange group.  Mirrors ``distributed.lower_distributed_window``'s
        schedule; infeasible geometry (indivisible mesh, k·h too deep for
        the shard) predicts ``inf`` like a failed compile would measure."""
        if mesh is None:
            return None
        mesh_shape = (dict(mesh.shape) if hasattr(mesh, "shape")
                      else dict(mesh))
        g0 = next(iter(grids.values()))
        interior = tuple(g0.shape)
        batch = max(1, int(g0.batch or 1))
        halos = {n: tuple(g.halo) for n, g in grids.items()}
        itemsize = np.dtype(g0.dtype).itemsize
        steps = max(1, int(steps))
        if swap is None:
            # the tuner measures a single application for swap-less targets
            steps, window, windows = 1, 1, 1
            depth = 1
        else:
            window = min(max(1, int(fuse)), steps)
            windows = -(-steps // window)
            depth = min(backend.time_steps * _tl.backend_time_block(backend),
                        window)
        h_max = max((h for hs in halos.values() for h in hs), default=0)
        if h_max == 0:
            depth = 1
        try:
            spec = _halo.HaloSpec.build(halos, backend.grid_axes, interior,
                                        mesh_shape, depth=depth, swap=swap)
        except ValueError:
            return float("inf")
        sb = self.step_bytes(kernel, halos, spec.local_shape, st.xla(),
                             swap, g0.dtype)
        if sb is None:
            return None
        per_step, _ = sb
        if not math.isfinite(per_step):
            return float("inf")
        crate = self.rate_for("xla", g0.dtype)
        # measured inter-shard rate when the candidate mesh names real
        # devices; the fixed default for shape-only meshes
        lrate = self.rate_for("link", g0.dtype, mesh=mesh)
        coll_w = spec.window_collective_bytes(window, itemsize, batch=batch)
        groups_w = sum(c for c, _d in spec.group_depths(window))
        compute = (batch * steps * per_step / crate.bytes_per_s
                   + windows * crate.overhead_s)
        comm = windows * (coll_w / lrate.bytes_per_s
                          + groups_w * lrate.overhead_s)
        return compute + comm


# -- shared default models (one calibration per process per cache dir) -----
_MODELS: Dict[Optional[str], CostModel] = {}


def default_model(cache_dir: Optional[str] = None) -> CostModel:
    """Process-wide calibrated model per cache directory — the one
    ``autotune.tune`` builds when pruning without an explicit model, so
    repeated tunes share probes and memoized traffic."""
    m = _MODELS.get(cache_dir)
    if m is None:
        m = CostModel(cache_dir=cache_dir, calibrate=True)
        _MODELS[cache_dir] = m
    return m


def reset_default_models() -> None:
    """Drop shared models (tests / simulating a fresh process)."""
    _MODELS.clear()
