"""Distributed stencil runtime: shard_map domain decomposition + halo
exchange (beyond-paper — the paper is single-node; this layer is what makes
the technique runnable on pods).

Design
------
* The stencil grid's axes are mapped onto mesh axes (``backend.grid_axes``,
  e.g. ``('pod', 'data', 'model')`` splits a 3-D domain across all 512 chips
  of the multi-pod mesh).
* Each shard holds its local interior block.  Before applying the kernel,
  each decomposed axis exchanges ``h``-wide edge slabs with its mesh
  neighbors via ``lax.ppermute`` (devices at the global boundary receive
  zeros — matching the zero-filled grid halo).
* ``overlap=True`` splits the local update into an interior pass (which
  does *not* depend on the exchanged halos) and boundary-strip passes
  (which do).  XLA's latency-hiding scheduler can then overlap the
  ppermute transfers with the interior compute — the stencil analogue of
  the compute/comm overlap used in large-scale LM training.
* The per-shard compute reuses the single-device lowerings (XLA or Pallas),
  so ``distributed(inner=pallas(...))`` composes the paper's templates with
  the pod-level decomposition.
* The fused engine path (``lower_distributed_window``) goes further: the
  ENTIRE fusion window — halo exchange, boundary bands, interior compute
  and the leapfrog swap for every step — lives inside ONE jitted
  shard_map'd ``lax.fori_loop``, so a window costs a single program
  dispatch and the latency-hiding scheduler overlaps each group's
  ppermutes with the deep-interior pre-pass across steps, not just
  within one.  All exchange geometry comes from ``core.halo.HaloSpec``.

Halo traffic per step per shard is ``h · (local surface)`` — the classic
reason stencils scale to thousands of nodes: the collective term shrinks
relative to compute as local volume grows.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import analysis, ir, lowering
from . import halo as _halo
from . import timeloop as _tl


def _halo_exchange(local: jnp.ndarray, axis: int, mesh_axis: str,
                   h: int, mesh: Mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (left_halo, right_halo) slabs of width ``h`` for ``local``,
    fetched from mesh neighbors along ``mesh_axis`` (zeros at the ends)."""
    k = mesh.shape[mesh_axis]
    ndim = local.ndim

    def edge(lo, hi):
        idx = tuple(slice(lo, hi) if a == axis else slice(None)
                    for a in range(ndim))
        return local[idx]

    if k == 1:
        zero = jnp.zeros_like(edge(0, h))
        return zero, zero
    # my right edge → right neighbor's left halo
    left_halo = lax.ppermute(edge(local.shape[axis] - h, local.shape[axis]),
                             mesh_axis, [(i, i + 1) for i in range(k - 1)])
    # my left edge → left neighbor's right halo
    right_halo = lax.ppermute(edge(0, h), mesh_axis,
                              [(i + 1, i) for i in range(k - 1)])
    return left_halo, right_halo


def lower_distributed(kernel: ir.StencilIR,
                      halos: Mapping[str, Tuple[int, ...]],
                      interior_shape: Tuple[int, ...],
                      region,
                      backend,
                      mesh: Optional[Mesh]):
    """Build ``fn(arrays, scalars) -> arrays`` running the kernel
    domain-decomposed over ``mesh``.

    Constraints: ``region`` must be None (whole-domain; use coefficient
    masks for PML in the distributed path — see regions.py) and global
    grid halos are treated as zero.
    """
    if mesh is None:
        raise ValueError("distributed backend requires launch(mesh=...)")
    if region is not None:
        raise ValueError("distributed backend updates the whole domain; "
                         "express PML via coefficient masks (regions.py)")
    info = analysis.analyze(kernel)
    ndim = kernel.ndim
    grid_axes = tuple(backend.grid_axes)
    in_grids = info.input_grids
    out_grids = info.output_grids
    all_grids = tuple(kernel.grid_params)
    gh = {g: info.halo_per_grid.get(g, (0,) * ndim) for g in all_grids}
    kernel_halos = {g: gh[g] for g in all_grids}

    # geometry + validation (divisibility, axis mapping) via HaloSpec
    spec = _halo.HaloSpec.build(gh, grid_axes, interior_shape,
                                dict(mesh.shape), depth=1)
    local_shape = spec.local_shape

    _k_inner = _tl.backend_time_block(backend)
    if (getattr(backend, "time_steps", 1) > 1
            or (_k_inner > 1 and getattr(backend, "swap", None) is not None)):
        return _lower_time_skewed(kernel, info, interior_shape, backend,
                                  mesh, grid_axes, local_shape, gh)

    inner = getattr(backend, "inner", None)
    if inner is not None and inner.kind == "pallas":
        from repro.kernels.stencil import codegen as _codegen

        def make_inner(reg):
            return _codegen.lower_pallas(kernel, kernel_halos, local_shape,
                                         reg, inner)
    else:
        def make_inner(reg):
            return lowering.lower_jax(kernel, kernel_halos, local_shape, reg)

    inner_full = make_inner(None)

    # boundary strips (per decomposed axis, both ends) for the overlap path
    strip_regions = []
    for ax, m in enumerate(grid_axes):
        if m is None:
            continue
        h = max(gh[g][ax] for g in all_grids)
        if h == 0:
            continue
        full = tuple((0, local_shape[a]) for a in range(ndim))
        lo = tuple((0, h) if a == ax else full[a] for a in range(ndim))
        hi = tuple((local_shape[a] - h, local_shape[a]) if a == ax else full[a]
                   for a in range(ndim))
        strip_regions.append(lo)
        strip_regions.append(hi)
    inner_strips = [make_inner(r) for r in strip_regions] if backend.overlap \
        else []

    specs = P(*grid_axes)

    def pad_with_halos(local_arrays):
        """Exchange halos and return per-grid halo-padded local arrays."""
        padded = {}
        for g, loc in local_arrays.items():
            arr = loc
            for ax in range(ndim):
                h = gh[g][ax]
                if h == 0:
                    continue
                m = grid_axes[ax]
                if m is None:
                    zshape = list(arr.shape)
                    zshape[ax] = h
                    lh = jnp.zeros(zshape, arr.dtype)
                    rh = lh
                else:
                    # halo slabs are exchanged on the *unpadded* axis
                    # extents of already-padded other axes — pad order is
                    # axis-by-axis so earlier axes are already padded; the
                    # exchange covers the padded extent of those axes.
                    lh, rh = _halo_exchange(arr, ax, m, h, mesh)
                arr = jnp.concatenate([lh, arr, rh], axis=ax)
            padded[g] = arr
        return padded

    def interior_only_pad(local_arrays):
        padded = {}
        for g, loc in local_arrays.items():
            pads = [(gh[g][ax], gh[g][ax]) for ax in range(ndim)]
            padded[g] = jnp.pad(loc, pads)
        return padded

    def crop(arr, g):
        idx = tuple(slice(gh[g][ax], gh[g][ax] + local_shape[ax])
                    for ax in range(ndim))
        return arr[idx]

    def sharded_step(local_arrays: Dict[str, jnp.ndarray],
                     scalars: Dict[str, jnp.ndarray]):
        if backend.overlap and inner_strips:
            # 1) interior pass on zero-halo padding — no comm dependency, so
            #    XLA can overlap it with the ppermutes issued below.
            pad0 = interior_only_pad(local_arrays)
            out0 = inner_full(pad0, scalars)
            final = {g: crop(out0[g], g) for g in out_grids}
            # 2) exchanged halos → recompute boundary strips from the
            #    *pristine* inputs (outputs may alias inputs via center
            #    reads) and patch them into the interior-pass result.
            pad1 = pad_with_halos(local_arrays)
            for strip_fn, reg in zip(inner_strips, strip_regions):
                sres = strip_fn(pad1, scalars)
                for g in out_grids:
                    loc = tuple(slice(b, e) for b, e in reg)
                    padd = tuple(slice(gh[g][ax] + b, gh[g][ax] + e)
                                 for ax, (b, e) in enumerate(reg))
                    final[g] = final[g].at[loc].set(sres[g][padd])
            return final
        padded = pad_with_halos(local_arrays)
        out = inner_full(padded, scalars)
        return {g: crop(out[g], g) for g in out_grids}

    shmapped = shard_map(
        sharded_step, mesh=mesh,
        in_specs=({g: specs for g in all_grids}, P()),
        out_specs={g: specs for g in out_grids},
        check_rep=False)

    jitted = jax.jit(shmapped)

    def fn(arrays: Dict[str, jnp.ndarray], scalars: Dict[str, jnp.ndarray]):
        """arrays are *full* (grid-halo'd) host arrays; the grid halo is
        assumed zero in the distributed path."""
        interiors = {}
        for g in all_grids:
            o = (np.asarray(arrays[g].shape) - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            interiors[g] = arrays[g][idx]
        scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
        out = jitted(interiors, scal)
        result = dict(arrays)
        for g in out_grids:
            o = (np.asarray(arrays[g].shape) - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            result[g] = arrays[g].at[idx].set(out[g])
        return result

    fn.jitted = jitted
    fn.shmapped = shmapped
    fn.mesh = mesh
    fn.partition_spec = specs
    fn.local_shape = local_shape
    fn.spec = spec
    return fn


# ---------------------------------------------------------------------------
# overlapped tiling / time skewing (paper §3) at pod level
# ---------------------------------------------------------------------------
def _lower_time_skewed(kernel, info, interior_shape, backend, mesh,
                       grid_axes, local_shape, gh):
    """k kernel applications per ONE (k·h)-wide halo exchange.

    Each shard exchanges halos of width ext[g] = (k−1)·h_max + h_g, then
    computes k steps on regions shrinking by h_max per step — the shells
    between k·h and the interior are computed redundantly by both
    neighbors (the classic redundant-compute/communication trade).  At
    global boundaries the (zero) grid-halo condition is re-imposed on the
    shells between steps so fused results match k separate exchanged
    steps exactly (validated in tests/test_distributed.py).

    A pallas ``inner`` carrying ``time_block=k_inner`` composes with the
    device-level skewing: ``time_steps`` then counts k_inner-deep temporal
    groups, so one exchange is k_outer·k_inner·h wide and covers
    k_outer·k_inner applications (the per-shard sub-steps currently run
    through the XLA shrinking-region lowering, which has the identical
    halo/shell geometry as the in-kernel Pallas temporal blocks).
    """
    k_inner = _tl.backend_time_block(backend)
    k = backend.time_steps * k_inner
    swap = backend.swap
    if swap is None:
        raise ValueError("time_steps > 1 requires swap=(older, newer)")
    ndim = kernel.ndim
    all_grids = tuple(kernel.grid_params)
    out_grids = info.output_grids
    if len(out_grids) != 1 or out_grids[0] != swap[0]:
        raise ValueError("time skewing supports single-output kernels "
                         "writing swap[0]")

    # the whole exchange geometry — pad widths ((k−1)·h_max + h_g per
    # coefficient axis, uniform k·h_max for the swap pair), feasibility
    # (k·h ≤ local extent), zero-fill axes — is HaloSpec's job
    spec = _halo.HaloSpec.build(gh, grid_axes, interior_shape,
                                dict(mesh.shape), depth=k, swap=swap)
    h_max = spec.h_max
    ext = {g: spec.ext_of(g) for g in all_grids}

    def pad_wide(local_arrays):
        padded = {}
        for g, arr in local_arrays.items():
            for ax in range(ndim):
                e = ext[g][ax]
                if e == 0:
                    continue
                m = grid_axes[ax]
                if m:
                    lh, rh = _halo_exchange(arr, ax, m, e, mesh)
                else:
                    zshape = list(arr.shape)
                    zshape[ax] = e
                    lh = jnp.zeros(zshape, arr.dtype)
                    rh = lh
                arr = jnp.concatenate([lh, arr, rh], axis=ax)
            padded[g] = arr
        return padded

    def zero_outside_global(arr, g):
        """Re-impose the zero grid-halo beyond the global boundary (edge
        shards only) — the shells an edge shard 'computes' there must not
        leak into later steps."""
        for ax in range(ndim):
            m = grid_axes[ax]
            e = ext[g][ax]
            if not m or e == 0:
                continue
            idx = lax.axis_index(m)
            n = mesh.shape[m]
            coord = jnp.arange(arr.shape[ax])
            inside_lo = (idx > 0) | (coord >= e)
            inside_hi = (idx < n - 1) | (coord < arr.shape[ax] - e)
            keep = (inside_lo & inside_hi)
            shape = [1] * ndim
            shape[ax] = arr.shape[ax]
            arr = arr * keep.reshape(shape).astype(arr.dtype)
        return arr

    def sharded_k_steps(local_arrays, scalars):
        padded = pad_wide(local_arrays)
        padded = {g: zero_outside_global(a, g) for g, a in padded.items()}
        older, newer = swap
        for i in range(k):
            step_fn = lowering.lower_jax(kernel, ext, local_shape,
                                         spec.step_region(i))
            out = step_fn(padded, scalars)
            new_field = zero_outside_global(out[older], older)
            padded = dict(padded)
            padded[older], padded[newer] = padded[newer], new_field
        # crop interiors; final field lives in `newer` after the last swap
        def crop(arr, g):
            idx = tuple(slice(ext[g][ax], ext[g][ax] + local_shape[ax])
                        for ax in range(ndim))
            return arr[idx]
        return {older: crop(padded[older], older),
                newer: crop(padded[newer], newer)}

    specs = P(*grid_axes)
    shmapped = shard_map(
        sharded_k_steps, mesh=mesh,
        in_specs=({g: specs for g in all_grids}, P()),
        out_specs={swap[0]: specs, swap[1]: specs},
        check_rep=False)
    jitted = jax.jit(shmapped)

    def fn(arrays, scalars):
        interiors = {}
        for g in all_grids:
            o = (np.asarray(arrays[g].shape)
                 - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            interiors[g] = arrays[g][idx]
        scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
        out = jitted(interiors, scal)
        result = dict(arrays)
        for g in out:
            o = (np.asarray(arrays[g].shape)
                 - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            result[g] = arrays[g].at[idx].set(out[g])
        return result

    fn.jitted = jitted
    fn.shmapped = shmapped
    fn.mesh = mesh
    fn.partition_spec = specs
    fn.local_shape = local_shape
    fn.spec = spec
    return fn


# ---------------------------------------------------------------------------
# fused sharded timeloop: ONE program per fusion window
# ---------------------------------------------------------------------------
def lower_distributed_window(kernel: ir.StencilIR,
                             interior_shape: Tuple[int, ...],
                             backend,
                             mesh: Optional[Mesh],
                             swap: Tuple[str, str],
                             window: int,
                             batch: int = 0,
                             differentiable: bool = False,
                             masked: bool = False):
    """Build ``fn(arrays, scalars) -> arrays`` advancing ``window``
    leapfrog steps in ONE jitted shard_map'd program.

    The window decomposes into depth-``k`` exchange groups
    (``k = time_steps × inner time_block``; ``HaloSpec.group_depths``):
    ``window // k`` identical groups run as a ``lax.fori_loop`` plus one
    unrolled remainder group — all inside the same XLA program, so a
    window pays a single dispatch instead of one per exchange.  Within a
    group the swap pair exchanges ONE k·h_max-wide halo and then runs k
    kernel applications on shrinking regions; the first application's
    deep interior (``HaloSpec.deep_interior``) is computed from
    local-only, zero-padded data *before* the exchanged slabs are
    consumed, so XLA's latency-hiding scheduler overlaps the ppermutes
    with interior compute, and only the boundary bands
    (``HaloSpec.boundary_bands``) wait for the network.  Coefficient
    grids are exchanged ONCE per window (their slabs are wide enough for
    every group) and carried through the loop as invariants.

    ``batch > 0`` runs B independent scenarios as a leading unsharded
    axis: grids are ``(B, *spatial)`` sharded ``P(None, *grid_axes)``,
    scalars are replicated ``(B,)`` arrays, and every per-shard step
    function is vmapped over the scenario axis — one program advances
    the whole batch on the whole mesh.

    Per-shard sub-steps run through the XLA shrinking-region lowering
    regardless of a Pallas ``inner`` — the inner's ``time_block`` sets
    exchange *depth* (geometry), matching the existing time-skewed path.
    Global grid halos are zero, re-imposed between fused steps at mesh
    edges.  Exchange geometry/traffic live on ``fn.spec`` (a
    ``core.halo.HaloSpec``) for the cost model and tests.

    ``differentiable=True`` makes the returned window reverse-mode
    differentiable: the forward program is wrapped in a ``jax.custom_vjp``
    whose backward pass is a SECOND jitted shard_map program
    (``fn.bwd_jitted``) that re-linearizes the per-shard window body with
    ``jax.vjp`` *inside* the shard_map region and pulls the cotangents
    back through it.  Because the vjp is taken on per-device code, every
    forward ``ppermute`` transposes to the reverse ``ppermute`` — the same
    slab moving the opposite way, accumulating into the neighbor's edge
    cells — i.e. exactly the geometry of ``fn.spec.transpose()`` (attached
    as ``fn.spec_T``).  The wavefront-pipelining structure is reused as
    is: the adjoint of the deep-interior pre-pass is again a deep-interior
    pass with no communication dependency, so the latency hiding works
    identically for cotangents.  Scalar cotangents are ``psum``-reduced
    across the mesh (each shard contributes its local share).  Residuals
    are the window *inputs* only — O(1) carries per window, composing with
    the √T checkpointing of ``core/adjoint.py``.

    ``masked=True`` (requires ``batch``) builds the serving variant
    ``fn(arrays, scalars, mask, start, limits)`` with the exact freeze
    semantics of ``lowering.lower_jax_window_masked`` — per-scenario
    spatial masks and step budgets — under sharding: the mask shards like
    a batched grid, frozen cells keep their values and travel through the
    halo exchange like any other cell, so a masked sharded run equals the
    masked single-device run.  Masked windows exchange at depth 1 (the
    freeze is applied between *every* step, which a depth-k group cannot
    honor).  Composes with ``differentiable``.
    """
    if mesh is None:
        raise ValueError("distributed backend requires launch(mesh=...)")
    if swap is None:
        raise ValueError("the distributed timeloop requires "
                         "swap=(older, newer)")
    info = analysis.analyze(kernel)
    ndim = kernel.ndim
    grid_axes = tuple(backend.grid_axes)
    if len(grid_axes) != ndim:
        raise ValueError(f"grid_axes must have {ndim} entries")
    all_grids = tuple(kernel.grid_params)
    out_grids = info.output_grids
    if len(out_grids) != 1 or out_grids[0] != swap[0]:
        raise ValueError("the distributed timeloop supports single-output "
                         "kernels writing swap[0]")
    gh = {g: info.halo_per_grid.get(g, (0,) * ndim) for g in all_grids}
    window = int(window)
    if window < 1:
        raise ValueError("window must be >= 1")
    mesh_shape = dict(mesh.shape)

    h_max = max((h for hs in gh.values() for h in hs), default=0)
    depth = backend.time_steps * _tl.backend_time_block(backend)
    if h_max == 0:
        if depth > 1:
            raise ValueError("time skewing needs a nonzero stencil halo")
        depth = 1
    if masked:
        if not batch:
            raise ValueError("masked distributed windows require batch=B "
                             "(the serving path)")
        # the spatial/temporal freeze applies between every step, which a
        # depth-k exchange group's shrinking regions cannot express
        depth = 1
    depth = min(depth, window)
    spec = _halo.HaloSpec.build(gh, grid_axes, interior_shape, mesh_shape,
                                depth=depth, swap=swap)   # validates
    local_shape = spec.local_shape
    groups = spec.group_depths(window)
    older, newer = swap
    coeffs = tuple(g for g in all_grids if g not in (older, newer))
    ext_main = {g: spec.ext_of(g) for g in all_grids}
    off = 1 if batch else 0

    def maybe_vmap(f):
        return jax.vmap(f, in_axes=(0, 0)) if batch else f

    def pad_exchanged(arr, widths):
        """Axis-by-axis halo pad: real ppermute slabs on decomposed axes,
        zeros elsewhere (the global zero grid-halo)."""
        for ax in range(ndim):
            e = widths[ax]
            if e == 0:
                continue
            m = grid_axes[ax]
            if m:
                lh, rh = _halo_exchange(arr, ax + off, m, e, mesh)
            else:
                zshape = list(arr.shape)
                zshape[ax + off] = e
                lh = jnp.zeros(zshape, arr.dtype)
                rh = lh
            arr = jnp.concatenate([lh, arr, rh], axis=ax + off)
        return arr

    def pad_zero(arr, widths):
        pads = [(0, 0)] * off + [(w, w) for w in widths]
        return jnp.pad(arr, pads)

    def zero_outside_global(arr, widths):
        """Re-impose the zero grid-halo beyond the global boundary on edge
        shards, so shells 'computed' there never leak into later steps."""
        for ax in range(ndim):
            m = grid_axes[ax]
            e = widths[ax]
            if not m or e == 0:
                continue
            idx = lax.axis_index(m)
            n = mesh_shape[m]
            extent = arr.shape[ax + off]
            coord = jnp.arange(extent)
            keep = (((idx > 0) | (coord >= e))
                    & ((idx < n - 1) | (coord < extent - e)))
            shape = [1] * arr.ndim
            shape[ax + off] = extent
            arr = arr * keep.reshape(shape).astype(arr.dtype)
        return arr

    def crop_local(arr, widths):
        idx = ((slice(None),) * off
               + tuple(slice(widths[ax], widths[ax] + local_shape[ax])
                       for ax in range(ndim)))
        return arr[idx]

    def reg_idx(widths, region):
        return ((slice(None),) * off
                + tuple(slice(w + b, w + e)
                        for w, (b, e) in zip(widths, region)))

    use_overlap = bool(getattr(backend, "overlap", True)) \
        and spec.overlap_feasible()

    def group_fns(d):
        """Step/pre/band functions of one depth-d exchange group."""
        sub = spec if d == spec.depth else spec.with_depth(d)
        # remainder groups keep reading the window-wide coefficient pads
        exts = {g: ext_main[g] for g in coeffs}
        for g in (older, newer):
            exts[g] = sub.ext_of(g)
        step_fns = [maybe_vmap(lowering.lower_jax(kernel, exts, local_shape,
                                                  sub.step_region(i)))
                    for i in range(d)]
        pre_fn = None
        band_fns = []
        if use_overlap:
            pre_fn = maybe_vmap(lowering.lower_jax(kernel, gh, local_shape,
                                                   sub.deep_interior()))
            band_fns = [(maybe_vmap(lowering.lower_jax(
                            kernel, exts, local_shape, breg)), breg)
                        for breg in sub.boundary_bands()]
        return sub, exts, step_fns, pre_fn, band_fns

    def run_group(carry, pcoeffs, zcoeffs, scalars, fns):
        sub, exts, step_fns, pre_fn, band_fns = fns
        ew = exts[older]
        padded = dict(pcoeffs)
        for g in (older, newer):
            padded[g] = zero_outside_global(
                pad_exchanged(carry[g], exts[g]), exts[g])
        for i, step_fn in enumerate(step_fns):
            if i == 0 and pre_fn is not None:
                # deep interior from local-only data — no dependency on the
                # ppermutes above, so the scheduler overlaps them with this
                pre_in = dict(zcoeffs)
                pre_in[older] = pad_zero(carry[older], gh[older])
                pre_in[newer] = pad_zero(carry[newer], gh[newer])
                pre_out = pre_fn(pre_in, scalars)[older]
                deep = sub.deep_interior()
                out_f = padded[older].at[reg_idx(ew, deep)].set(
                    pre_out[reg_idx(gh[older], deep)])
                for band_fn, breg in band_fns:
                    bres = band_fn(padded, scalars)[older]
                    out_f = out_f.at[reg_idx(ew, breg)].set(
                        bres[reg_idx(ew, breg)])
            else:
                out_f = step_fn(padded, scalars)[older]
            new_field = zero_outside_global(out_f, ew)
            padded = dict(padded)
            padded[older], padded[newer] = padded[newer], new_field
        return {older: crop_local(padded[older], ew),
                newer: crop_local(padded[newer], ew)}

    gspec = P(None, *grid_axes) if batch else P(*grid_axes)

    if masked:
        # one full-region step at depth-1 pad widths; freeze applied on the
        # local interiors between steps, exactly as the single-device
        # masked window does it in buffer space
        step_full = maybe_vmap(lowering.lower_jax(kernel, ext_main,
                                                  local_shape, None))
        act_shape = (batch,) + (1,) * ndim

        def sharded_body(local_arrays, scalars, mask, start, limits):
            pcoeffs = {g: zero_outside_global(
                           pad_exchanged(local_arrays[g], ext_main[g]),
                           ext_main[g])
                       for g in coeffs}

            def body(i, carry):
                padded = dict(pcoeffs)
                for g in (older, newer):
                    padded[g] = zero_outside_global(
                        pad_exchanged(carry[g], ext_main[g]), ext_main[g])
                out_i = crop_local(step_full(padded, scalars)[older],
                                   ext_main[older])
                act = ((start + i) < limits).reshape(act_shape)
                # spatial freeze first (masked cells keep the older
                # buffer), then the per-scenario rotation freeze
                frozen = jnp.where(mask, out_i, carry[older])
                return {older: jnp.where(act, carry[newer], carry[older]),
                        newer: jnp.where(act, frozen, carry[newer])}

            carry = {older: local_arrays[older], newer: local_arrays[newer]}
            return lax.fori_loop(0, window, body, carry)

        mask_spec = P(None, *grid_axes)
        shmapped = shard_map(
            sharded_body, mesh=mesh,
            in_specs=({g: gspec for g in all_grids}, P(), mask_spec,
                      P(), P()),
            out_specs={older: gspec, newer: gspec},
            check_rep=False)
    else:
        (m_groups, _), = groups[:1]
        rem = groups[1] if len(groups) > 1 else None
        main_fns = group_fns(depth)
        rem_fns = group_fns(rem[1]) if rem else None

        def sharded_body(local_arrays, scalars):
            # coefficients: exchanged once, loop-invariant through the
            # window
            pcoeffs = {g: zero_outside_global(
                           pad_exchanged(local_arrays[g], ext_main[g]),
                           ext_main[g])
                       for g in coeffs}
            zcoeffs = ({g: pad_zero(local_arrays[g], gh[g]) for g in coeffs}
                       if use_overlap else {})
            carry = {older: local_arrays[older], newer: local_arrays[newer]}
            if m_groups == 1:
                carry = run_group(carry, pcoeffs, zcoeffs, scalars,
                                  main_fns)
            else:
                carry = lax.fori_loop(
                    0, m_groups,
                    lambda _i, c: run_group(c, pcoeffs, zcoeffs, scalars,
                                            main_fns),
                    carry)
            if rem is not None:
                carry = run_group(carry, pcoeffs, zcoeffs, scalars, rem_fns)
            return carry

        shmapped = shard_map(
            sharded_body, mesh=mesh,
            in_specs=({g: gspec for g in all_grids}, P()),
            out_specs={older: gspec, newer: gspec},
            check_rep=False)

    jitted = jax.jit(shmapped)

    # -- adjoint program: jax.vjp of the per-shard body INSIDE shard_map ----
    # (so every forward ppermute transposes to the reverse ppermute — the
    # fn.spec_T geometry — and the deep-interior latency hiding applies to
    # the cotangents too); scalar cotangents psum-reduce across the mesh
    bwd_jitted = None
    if differentiable:
        axes = tuple(mesh.axis_names)

        def _psum_scal(d_scal):
            return {n: lax.psum(v, axes) for n, v in d_scal.items()}

        if masked:
            def sharded_adjoint(local_in, cot, scalars, mask, start,
                                limits):
                def f(a, s):
                    return sharded_body(a, s, mask, start, limits)
                _, vjp_fn = jax.vjp(f, local_in, scalars)
                d_in, d_scal = vjp_fn(dict(cot))
                return d_in, _psum_scal(d_scal)

            bwd_shmapped = shard_map(
                sharded_adjoint, mesh=mesh,
                in_specs=({g: gspec for g in all_grids},
                          {older: gspec, newer: gspec}, P(),
                          P(None, *grid_axes), P(), P()),
                out_specs=({g: gspec for g in all_grids}, P()),
                check_rep=False)
        else:
            def sharded_adjoint(local_in, cot, scalars):
                _, vjp_fn = jax.vjp(sharded_body, local_in, scalars)
                d_in, d_scal = vjp_fn(dict(cot))
                return d_in, _psum_scal(d_scal)

            bwd_shmapped = shard_map(
                sharded_adjoint, mesh=mesh,
                in_specs=({g: gspec for g in all_grids},
                          {older: gspec, newer: gspec}, P()),
                out_specs=({g: gspec for g in all_grids}, P()),
                check_rep=False)
        bwd_jitted = jax.jit(bwd_shmapped)

    if differentiable and not masked:
        @jax.custom_vjp
        def core(interiors, scal):
            return jitted(interiors, scal)

        def _core_fwd(interiors, scal):
            # residuals are the window INPUTS (one carry), not per-step
            # intermediates — the backward program re-linearizes from them
            return jitted(interiors, scal), (interiors, scal)

        def _core_bwd(res, cot):
            interiors, scal = res
            return bwd_jitted(interiors, dict(cot), scal)

        core.defvjp(_core_fwd, _core_bwd)
    else:
        core = jitted

    def _masked_core(mask, start, limits):
        """custom_vjp over (interiors, scalars) with the non-differentiable
        mask/start/limits operands closed over (they are concrete per
        call; the compiled programs underneath are shared)."""
        @jax.custom_vjp
        def core_m(interiors, scal):
            return jitted(interiors, scal, mask, start, limits)

        def fwd(interiors, scal):
            return (jitted(interiors, scal, mask, start, limits),
                    (interiors, scal))

        def bwd(res, cot):
            interiors, scal = res
            return bwd_jitted(interiors, dict(cot), scal, mask, start,
                              limits)

        core_m.defvjp(fwd, bwd)
        return core_m

    def _interior_idx(arr):
        o = (np.asarray(arr.shape[off:]) - np.asarray(interior_shape)) // 2
        return ((slice(None),) * off
                + tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim)))

    def _scal_in(v):
        # floating dtypes pass through (the f64 adjoint path must not be
        # silently truncated); everything else normalizes to f32 as before
        a = jnp.asarray(v)
        return a if jnp.issubdtype(a.dtype, jnp.floating) \
            else a.astype(jnp.float32)

    if masked:
        def fn(arrays: Dict[str, jnp.ndarray],
               scalars: Dict[str, jnp.ndarray],
               mask, start, limits):
            """arrays are *full* (grid-halo'd) host arrays with a leading
            batch axis; the grid halo is assumed zero."""
            interiors = {g: arrays[g][_interior_idx(arrays[g])]
                         for g in all_grids}
            scal = {n: _scal_in(v) for n, v in scalars.items()}
            mask = jnp.asarray(mask, bool)
            start = jnp.asarray(start, jnp.int32)
            limits = jnp.asarray(limits, jnp.int32)
            if differentiable:
                out = _masked_core(mask, start, limits)(interiors, scal)
            else:
                out = jitted(interiors, scal, mask, start, limits)
            result = dict(arrays)
            for g in (older, newer):
                full = jnp.asarray(arrays[g])
                result[g] = full.at[_interior_idx(full)].set(out[g])
            return result
    else:
        def fn(arrays: Dict[str, jnp.ndarray],
               scalars: Dict[str, jnp.ndarray]):
            """arrays are *full* (grid-halo'd) host arrays, optionally with
            a leading batch axis; the grid halo is assumed zero."""
            interiors = {g: arrays[g][_interior_idx(arrays[g])]
                         for g in all_grids}
            scal = {n: _scal_in(v) for n, v in scalars.items()}
            out = core(interiors, scal)
            result = dict(arrays)
            for g in (older, newer):
                full = jnp.asarray(arrays[g])
                result[g] = full.at[_interior_idx(full)].set(out[g])
            return result

    fn.jitted = jitted
    fn.shmapped = shmapped
    fn.bwd_jitted = bwd_jitted
    fn.mesh = mesh
    fn.partition_spec = gspec
    fn.local_shape = local_shape
    fn.spec = spec
    fn.spec_T = spec.transpose()
    fn.depth = depth
    fn.window = window
    fn.groups = groups
    fn.masked = masked
    fn.differentiable = differentiable
    return fn
