"""Distributed stencil runtime: shard_map domain decomposition + halo
exchange (beyond-paper — the paper is single-node; this layer is what makes
the technique runnable on pods).

Design
------
* The stencil grid's axes are mapped onto mesh axes (``backend.grid_axes``,
  e.g. ``('pod', 'data', 'model')`` splits a 3-D domain across all 512 chips
  of the multi-pod mesh).
* Each shard holds its local interior block.  Before applying the kernel,
  each decomposed axis exchanges ``h``-wide edge slabs with its mesh
  neighbors via ``lax.ppermute`` (devices at the global boundary receive
  zeros — matching the zero-filled grid halo).
* ``overlap=True`` splits the local update into an interior pass (which
  does *not* depend on the exchanged halos) and boundary-strip passes
  (which do).  XLA's latency-hiding scheduler can then overlap the
  ppermute transfers with the interior compute — the stencil analogue of
  the compute/comm overlap used in large-scale LM training.
* The per-shard compute reuses the single-device lowerings (XLA or Pallas),
  so ``distributed(inner=pallas(...))`` composes the paper's templates with
  the pod-level decomposition.

Halo traffic per step per shard is ``h · (local surface)`` — the classic
reason stencils scale to thousands of nodes: the collective term shrinks
relative to compute as local volume grows.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import analysis, ir, lowering
from . import timeloop as _tl


def _halo_exchange(local: jnp.ndarray, axis: int, mesh_axis: str,
                   h: int, mesh: Mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (left_halo, right_halo) slabs of width ``h`` for ``local``,
    fetched from mesh neighbors along ``mesh_axis`` (zeros at the ends)."""
    k = mesh.shape[mesh_axis]
    ndim = local.ndim

    def edge(lo, hi):
        idx = tuple(slice(lo, hi) if a == axis else slice(None)
                    for a in range(ndim))
        return local[idx]

    if k == 1:
        zero = jnp.zeros_like(edge(0, h))
        return zero, zero
    # my right edge → right neighbor's left halo
    left_halo = lax.ppermute(edge(local.shape[axis] - h, local.shape[axis]),
                             mesh_axis, [(i, i + 1) for i in range(k - 1)])
    # my left edge → left neighbor's right halo
    right_halo = lax.ppermute(edge(0, h), mesh_axis,
                              [(i + 1, i) for i in range(k - 1)])
    return left_halo, right_halo


def lower_distributed(kernel: ir.StencilIR,
                      halos: Mapping[str, Tuple[int, ...]],
                      interior_shape: Tuple[int, ...],
                      region,
                      backend,
                      mesh: Optional[Mesh]):
    """Build ``fn(arrays, scalars) -> arrays`` running the kernel
    domain-decomposed over ``mesh``.

    Constraints: ``region`` must be None (whole-domain; use coefficient
    masks for PML in the distributed path — see regions.py) and global
    grid halos are treated as zero.
    """
    if mesh is None:
        raise ValueError("distributed backend requires launch(mesh=...)")
    if region is not None:
        raise ValueError("distributed backend updates the whole domain; "
                         "express PML via coefficient masks (regions.py)")
    info = analysis.analyze(kernel)
    ndim = kernel.ndim
    grid_axes = tuple(backend.grid_axes)
    if len(grid_axes) != ndim:
        raise ValueError(f"grid_axes must have {ndim} entries")
    for ax, m in enumerate(grid_axes):
        if m is None:
            continue
        if interior_shape[ax] % mesh.shape[m]:
            raise ValueError(
                f"domain axis {ax} ({interior_shape[ax]}) not divisible by "
                f"mesh axis '{m}' ({mesh.shape[m]})")

    local_shape = tuple(
        s // (mesh.shape[m] if m else 1)
        for s, m in zip(interior_shape, grid_axes))

    in_grids = info.input_grids
    out_grids = info.output_grids
    all_grids = tuple(kernel.grid_params)
    gh = {g: info.halo_per_grid.get(g, (0,) * ndim) for g in all_grids}
    kernel_halos = {g: gh[g] for g in all_grids}

    _k_inner = _tl.backend_time_block(backend)
    if (getattr(backend, "time_steps", 1) > 1
            or (_k_inner > 1 and getattr(backend, "swap", None) is not None)):
        return _lower_time_skewed(kernel, info, interior_shape, backend,
                                  mesh, grid_axes, local_shape, gh)

    inner = getattr(backend, "inner", None)
    if inner is not None and inner.kind == "pallas":
        from repro.kernels.stencil import codegen as _codegen

        def make_inner(reg):
            return _codegen.lower_pallas(kernel, kernel_halos, local_shape,
                                         reg, inner)
    else:
        def make_inner(reg):
            return lowering.lower_jax(kernel, kernel_halos, local_shape, reg)

    inner_full = make_inner(None)

    # boundary strips (per decomposed axis, both ends) for the overlap path
    strip_regions = []
    for ax, m in enumerate(grid_axes):
        if m is None:
            continue
        h = max(gh[g][ax] for g in all_grids)
        if h == 0:
            continue
        full = tuple((0, local_shape[a]) for a in range(ndim))
        lo = tuple((0, h) if a == ax else full[a] for a in range(ndim))
        hi = tuple((local_shape[a] - h, local_shape[a]) if a == ax else full[a]
                   for a in range(ndim))
        strip_regions.append(lo)
        strip_regions.append(hi)
    inner_strips = [make_inner(r) for r in strip_regions] if backend.overlap \
        else []

    specs = P(*grid_axes)

    def pad_with_halos(local_arrays):
        """Exchange halos and return per-grid halo-padded local arrays."""
        padded = {}
        for g, loc in local_arrays.items():
            arr = loc
            for ax in range(ndim):
                h = gh[g][ax]
                if h == 0:
                    continue
                m = grid_axes[ax]
                if m is None:
                    zshape = list(arr.shape)
                    zshape[ax] = h
                    lh = jnp.zeros(zshape, arr.dtype)
                    rh = lh
                else:
                    # halo slabs are exchanged on the *unpadded* axis
                    # extents of already-padded other axes — pad order is
                    # axis-by-axis so earlier axes are already padded; the
                    # exchange covers the padded extent of those axes.
                    lh, rh = _halo_exchange(arr, ax, m, h, mesh)
                arr = jnp.concatenate([lh, arr, rh], axis=ax)
            padded[g] = arr
        return padded

    def interior_only_pad(local_arrays):
        padded = {}
        for g, loc in local_arrays.items():
            pads = [(gh[g][ax], gh[g][ax]) for ax in range(ndim)]
            padded[g] = jnp.pad(loc, pads)
        return padded

    def crop(arr, g):
        idx = tuple(slice(gh[g][ax], gh[g][ax] + local_shape[ax])
                    for ax in range(ndim))
        return arr[idx]

    def sharded_step(local_arrays: Dict[str, jnp.ndarray],
                     scalars: Dict[str, jnp.ndarray]):
        if backend.overlap and inner_strips:
            # 1) interior pass on zero-halo padding — no comm dependency, so
            #    XLA can overlap it with the ppermutes issued below.
            pad0 = interior_only_pad(local_arrays)
            out0 = inner_full(pad0, scalars)
            final = {g: crop(out0[g], g) for g in out_grids}
            # 2) exchanged halos → recompute boundary strips from the
            #    *pristine* inputs (outputs may alias inputs via center
            #    reads) and patch them into the interior-pass result.
            pad1 = pad_with_halos(local_arrays)
            for strip_fn, reg in zip(inner_strips, strip_regions):
                sres = strip_fn(pad1, scalars)
                for g in out_grids:
                    loc = tuple(slice(b, e) for b, e in reg)
                    padd = tuple(slice(gh[g][ax] + b, gh[g][ax] + e)
                                 for ax, (b, e) in enumerate(reg))
                    final[g] = final[g].at[loc].set(sres[g][padd])
            return final
        padded = pad_with_halos(local_arrays)
        out = inner_full(padded, scalars)
        return {g: crop(out[g], g) for g in out_grids}

    shmapped = shard_map(
        sharded_step, mesh=mesh,
        in_specs=({g: specs for g in all_grids}, P()),
        out_specs={g: specs for g in out_grids},
        check_rep=False)

    jitted = jax.jit(shmapped)

    def fn(arrays: Dict[str, jnp.ndarray], scalars: Dict[str, jnp.ndarray]):
        """arrays are *full* (grid-halo'd) host arrays; the grid halo is
        assumed zero in the distributed path."""
        interiors = {}
        for g in all_grids:
            o = (np.asarray(arrays[g].shape) - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            interiors[g] = arrays[g][idx]
        scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
        out = jitted(interiors, scal)
        result = dict(arrays)
        for g in out_grids:
            o = (np.asarray(arrays[g].shape) - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            result[g] = arrays[g].at[idx].set(out[g])
        return result

    fn.jitted = jitted
    fn.shmapped = shmapped
    fn.mesh = mesh
    fn.partition_spec = specs
    fn.local_shape = local_shape
    return fn


# ---------------------------------------------------------------------------
# overlapped tiling / time skewing (paper §3) at pod level
# ---------------------------------------------------------------------------
def _lower_time_skewed(kernel, info, interior_shape, backend, mesh,
                       grid_axes, local_shape, gh):
    """k kernel applications per ONE (k·h)-wide halo exchange.

    Each shard exchanges halos of width ext[g] = (k−1)·h_max + h_g, then
    computes k steps on regions shrinking by h_max per step — the shells
    between k·h and the interior are computed redundantly by both
    neighbors (the classic redundant-compute/communication trade).  At
    global boundaries the (zero) grid-halo condition is re-imposed on the
    shells between steps so fused results match k separate exchanged
    steps exactly (validated in tests/test_distributed.py).

    A pallas ``inner`` carrying ``time_block=k_inner`` composes with the
    device-level skewing: ``time_steps`` then counts k_inner-deep temporal
    groups, so one exchange is k_outer·k_inner·h wide and covers
    k_outer·k_inner applications (the per-shard sub-steps currently run
    through the XLA shrinking-region lowering, which has the identical
    halo/shell geometry as the in-kernel Pallas temporal blocks).
    """
    k_inner = _tl.backend_time_block(backend)
    k = backend.time_steps * k_inner
    swap = backend.swap
    if swap is None:
        raise ValueError("time_steps > 1 requires swap=(older, newer)")
    ndim = kernel.ndim
    h_max = max(info.halo) if info.halo else 0
    if h_max == 0:
        raise ValueError("time skewing needs a nonzero stencil halo")
    all_grids = tuple(kernel.grid_params)
    out_grids = info.output_grids
    if len(out_grids) != 1 or out_grids[0] != swap[0]:
        raise ValueError("time skewing supports single-output kernels "
                         "writing swap[0]")

    # uniform padded indexing: decomposed axes exchange (k−1)·h_max + h_g
    # wide slabs; non-decomposed axes zero-pad the same width (the global
    # zero grid-halo).  The swap pair must share geometry (they trade
    # buffers between steps) → both get the full k·h_max.
    ext = {g: tuple((k - 1) * h_max + gh[g][ax] for ax in range(ndim))
           for g in all_grids}
    for g in swap:
        ext[g] = (k * h_max,) * ndim
    for ax, m in enumerate(grid_axes):
        if m and k * h_max > local_shape[ax]:
            raise ValueError("k·h halo exceeds local extent; reduce "
                             "time_steps or mesh split")

    def pad_wide(local_arrays):
        padded = {}
        for g, arr in local_arrays.items():
            for ax in range(ndim):
                e = ext[g][ax]
                if e == 0:
                    continue
                m = grid_axes[ax]
                if m:
                    lh, rh = _halo_exchange(arr, ax, m, e, mesh)
                else:
                    zshape = list(arr.shape)
                    zshape[ax] = e
                    lh = jnp.zeros(zshape, arr.dtype)
                    rh = lh
                arr = jnp.concatenate([lh, arr, rh], axis=ax)
            padded[g] = arr
        return padded

    def zero_outside_global(arr, g):
        """Re-impose the zero grid-halo beyond the global boundary (edge
        shards only) — the shells an edge shard 'computes' there must not
        leak into later steps."""
        for ax in range(ndim):
            m = grid_axes[ax]
            e = ext[g][ax]
            if not m or e == 0:
                continue
            idx = lax.axis_index(m)
            n = mesh.shape[m]
            coord = jnp.arange(arr.shape[ax])
            inside_lo = (idx > 0) | (coord >= e)
            inside_hi = (idx < n - 1) | (coord < arr.shape[ax] - e)
            keep = (inside_lo & inside_hi)
            shape = [1] * ndim
            shape[ax] = arr.shape[ax]
            arr = arr * keep.reshape(shape).astype(arr.dtype)
        return arr

    def sharded_k_steps(local_arrays, scalars):
        padded = pad_wide(local_arrays)
        padded = {g: zero_outside_global(a, g) for g, a in padded.items()}
        older, newer = swap
        for i in range(k):
            mshell = (k - 1 - i) * h_max
            region = tuple(
                (-mshell, local_shape[ax] + mshell) if grid_axes[ax]
                else (0, local_shape[ax])
                for ax in range(ndim))
            step_fn = lowering.lower_jax(kernel, ext, local_shape, region)
            out = step_fn(padded, scalars)
            new_field = zero_outside_global(out[older], older)
            padded = dict(padded)
            padded[older], padded[newer] = padded[newer], new_field
        # crop interiors; final field lives in `newer` after the last swap
        def crop(arr, g):
            idx = tuple(slice(ext[g][ax], ext[g][ax] + local_shape[ax])
                        for ax in range(ndim))
            return arr[idx]
        return {older: crop(padded[older], older),
                newer: crop(padded[newer], newer)}

    specs = P(*grid_axes)
    shmapped = shard_map(
        sharded_k_steps, mesh=mesh,
        in_specs=({g: specs for g in all_grids}, P()),
        out_specs={swap[0]: specs, swap[1]: specs},
        check_rep=False)
    jitted = jax.jit(shmapped)

    def fn(arrays, scalars):
        interiors = {}
        for g in all_grids:
            o = (np.asarray(arrays[g].shape)
                 - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            interiors[g] = arrays[g][idx]
        scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
        out = jitted(interiors, scal)
        result = dict(arrays)
        for g in out:
            o = (np.asarray(arrays[g].shape)
                 - np.asarray(interior_shape)) // 2
            idx = tuple(slice(int(o[ax]), int(o[ax]) + interior_shape[ax])
                        for ax in range(ndim))
            result[g] = arrays[g].at[idx].set(out[g])
        return result

    fn.jitted = jitted
    fn.shmapped = shmapped
    fn.mesh = mesh
    fn.partition_spec = specs
    fn.local_shape = local_shape
    return fn
