"""Paper Table 4 kernel suite: star/box × 2D/3D × order 1..4 + Jacobi kernels.

Kernels are synthesized as DSL *source text* with literal coefficients and
run through the real ``@st.kernel`` frontend — so the suite exercises the
parser/analysis path exactly like hand-written code, while staying compact.
Coefficients are deterministic (AN5D-style distinct per tap, normalized so
iterated application stays bounded).
"""
from __future__ import annotations

import itertools
from typing import Dict, Tuple

from . import dsl as st

__all__ = ["get_kernel", "KERNEL_NAMES", "kernel_meta", "make_grids",
           "swap_pair"]


def _fmt(x: float) -> str:
    return f"{x:.6f}"


def _star_source(name: str, ndim: int, r: int) -> str:
    taps = [((0,) * ndim)]
    for ax, d in itertools.product(range(ndim), range(1, r + 1)):
        for sgn in (-1, 1):
            off = [0] * ndim
            off[ax] = sgn * d
            taps.append(tuple(off))
    return _source_from_taps(name, ndim, taps)


def _box_source(name: str, ndim: int, r: int) -> str:
    taps = list(itertools.product(range(-r, r + 1), repeat=ndim))
    return _source_from_taps(name, ndim, taps)


def _source_from_taps(name: str, ndim: int, taps) -> str:
    n = len(taps)
    # center-heavy normalized weights: w_i = a_i / sum(a), a_center = n
    raw = []
    for i, off in enumerate(taps):
        raw.append(float(n) if not any(off) else 1.0 / (2.0 + (i % 7)))
    s = sum(raw)
    terms = []
    for off, a in zip(taps, raw):
        offs = ", ".join(str(o) for o in off)
        terms.append(f"{_fmt(a / s)} * u.at({offs})")
    body = "\n        + ".join(terms)
    params = "u: st.grid, v: st.grid"
    center = ", ".join("0" for _ in range(ndim))
    return (
        f"def {name}({params}):\n"
        f"    v.at({center}).set({body})\n"
    )


_JACOBI = {
    # name: (ndim, source)
    "j2d5pt": (2, """
def j2d5pt(u: st.grid, v: st.grid):
    v.at(0, 0).set(0.20 * (u.at(0, 0) + u.at(-1, 0) + u.at(1, 0)
                   + u.at(0, -1) + u.at(0, 1)))
"""),
    "j2d9pt": (2, """
def j2d9pt(u: st.grid, v: st.grid):
    v.at(0, 0).set(0.2 * u.at(0, 0)
                   + 0.1 * (u.at(-1, 0) + u.at(1, 0) + u.at(0, -1) + u.at(0, 1))
                   + 0.1 * (u.at(-2, 0) + u.at(2, 0) + u.at(0, -2) + u.at(0, 2)))
"""),
    "j2d9pt_gol": (2, """
def j2d9pt_gol(u: st.grid, v: st.grid):
    v.at(0, 0).set(0.2 * u.at(0, 0)
                   + 0.1 * (u.at(-1, -1) + u.at(-1, 0) + u.at(-1, 1)
                   + u.at(0, -1) + u.at(0, 1)
                   + u.at(1, -1) + u.at(1, 0) + u.at(1, 1)))
"""),
    "j3d27pt": (3, """
def j3d27pt(u: st.grid, v: st.grid):
    v.at(0, 0, 0).set(0.5 * u.at(0, 0, 0)
        + 0.02 * (u.at(-1, -1, -1) + u.at(-1, -1, 0) + u.at(-1, -1, 1)
        + u.at(-1, 0, -1) + u.at(-1, 0, 0) + u.at(-1, 0, 1)
        + u.at(-1, 1, -1) + u.at(-1, 1, 0) + u.at(-1, 1, 1)
        + u.at(0, -1, -1) + u.at(0, -1, 0) + u.at(0, -1, 1)
        + u.at(0, 0, -1) + u.at(0, 0, 1)
        + u.at(0, 1, -1) + u.at(0, 1, 0) + u.at(0, 1, 1)
        + u.at(1, -1, -1) + u.at(1, -1, 0) + u.at(1, -1, 1)
        + u.at(1, 0, -1) + u.at(1, 0, 0) + u.at(1, 0, 1)
        + u.at(1, 1, -1) + u.at(1, 1, 0) + u.at(1, 1, 1)))
"""),
}


def _make(name: str) -> st.Kernel:
    if name in _JACOBI:
        src = _JACOBI[name][1]
    elif name.startswith("star"):
        ndim, r = int(name[4]), int(name[6])
        src = _star_source(name, ndim, r)
    elif name.startswith("box"):
        ndim, r = int(name[3]), int(name[5])
        src = _box_source(name, ndim, r)
    else:
        raise KeyError(name)
    ns: Dict = {"st": st}
    exec(compile(src, f"<suite:{name}>", "exec"), ns)  # noqa: S102
    fn = ns[name]
    fn.__stencil_source__ = src
    return st.kernel(fn)


KERNEL_NAMES: Tuple[str, ...] = tuple(
    [f"star{d}d{r}r" for d in (2, 3) for r in (1, 2, 3, 4)]
    + [f"box{d}d{r}r" for d in (2, 3) for r in (1, 2, 3, 4)]
    + list(_JACOBI)
)

_CACHE: Dict[str, st.Kernel] = {}


def get_kernel(name: str) -> st.Kernel:
    if name not in _CACHE:
        _CACHE[name] = _make(name)
    return _CACHE[name]


def kernel_meta(name: str):
    """(ndim, shape, order) for reporting (paper Table 4 columns)."""
    k = get_kernel(name)
    return k.info.ndim, k.info.shape, k.info.order


def make_grids(name: str, shape: Tuple[int, ...] = None,
               seed: int = 0) -> Dict[str, st.grid]:
    """Ready-to-launch grids for a suite kernel (randomized interiors,
    zero halos), keyed by the kernel's grid-parameter names — the common
    setup for the time-loop benchmarks and the autotuner."""
    k = get_kernel(name)
    if shape is None:
        if k.info.ndim == 2:
            shape = (64, 64)
        elif k.info.order <= 2:
            shape = (16, 16, 32)
        else:
            # high-order 3D kernels (e.g. the paper's 25-point star3d4r)
            # need extents that admit in-kernel temporal blocking up to
            # k=4: the k·h expanded halo (16 cells at order 4) must fit
            # the block on every axis
            shape = (32, 32, 64)
    return {g: st.grid(dtype=st.f32, shape=shape,
                       order=k.info.order).randomize(seed + i)
            for i, g in enumerate(k.ir.grid_params)}


def swap_pair(name: str) -> Tuple[str, str]:
    """The (written, read) leapfrog buffer pair of a suite kernel —
    every suite kernel is ``u → v``, so this is ``("v", "u")``."""
    k = get_kernel(name)
    return (k.ir.output_grids()[0], k.ir.input_grids()[0])
