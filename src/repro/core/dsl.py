"""StencilPy user-facing DSL (paper Table 1 constructs), hosted in Python.

Usage mirrors paper Listing 1::

    from repro.core import dsl as st

    @st.kernel
    def star2d1r(u: st.grid, v: st.grid):
        v.at(0, 0).set(0.5 * u.at(0, 0)
                       + 0.125 * (u.at(-1, 0) + u.at(1, 0))
                       + 0.125 * (u.at(0, -1) + u.at(0, 1)))

    @st.target
    def run(u: st.grid, v: st.grid, iters: st.i32):
        for _t in range(iters):
            st.map(e=u.shape)(star2d1r)(u, v)
            (v, u) = (u, v)

    u = st.grid(dtype=st.f32, shape=(512, 512), order=1)
    v = st.grid(dtype=st.f32, shape=(512, 512), order=1)
    st.launch(backend=st.pallas(template="gmem"))(run)(u, v, 10)

Constructs: ``kernel``, ``target``, ``map``, ``launch``, ``at``/``at.set``
(inside kernels), ``grid``.  Backends: ``xla`` (pure-jnp/XLA), ``pallas``
(TPU Pallas codegen; ``interpret=True`` on CPU), ``distributed`` (shard_map
domain decomposition), plus a ``cuda`` compatibility alias so paper Listing 1
runs verbatim.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import analysis as _analysis
from . import frontend as _frontend
from . import ir as _ir
from . import lowering as _lowering

__all__ = [
    "grid", "kernel", "target", "map", "timeloop", "launch",
    "differentiable_timeloop",
    "f32", "f64", "bf16", "i32", "i64",
    "xla", "pallas", "tpu", "cuda", "distributed",
    "Kernel", "LaunchResult", "TimeloopResult",
]


# --------------------------------------------------------------------------
# dtype markers
# --------------------------------------------------------------------------
class _DType:
    def __init__(self, name: str, np_dtype):
        self.name = name
        self.dtype = np_dtype

    def __repr__(self):
        return f"st.{self.name}"


f32 = _DType("f32", jnp.float32)
f64 = _DType("f64", jnp.float64)
bf16 = _DType("bf16", jnp.bfloat16)
i32 = _DType("i32", jnp.int32)
i64 = _DType("i64", jnp.int64)


# --------------------------------------------------------------------------
# grid
# --------------------------------------------------------------------------
class grid:
    """A stencil data grid: ``shape`` interior points + ``order`` halo cells
    on each side of every axis (paper §2.1).  Also used as the kernel
    parameter type annotation (``u: st.grid``).

    ``batch=B`` adds a leading *scenario* axis: the grid holds B independent
    copies of the (halo-padded) domain, advanced together by
    ``st.timeloop(..., batch=B)`` in one compiled program.  The scenario
    axis carries no halo."""

    def __init__(self, dtype: _DType = f32, shape: Tuple[int, ...] = (),
                 order: int = 0, data: Optional[jnp.ndarray] = None,
                 batch: Optional[int] = None):
        self.shape = tuple(shape)
        self.order = int(order)
        self.batch = int(batch) if batch else None
        self.dtype = dtype.dtype if isinstance(dtype, _DType) else dtype
        full = tuple(s + 2 * self.order for s in self.shape)
        if self.batch:
            full = (self.batch,) + full
        if data is not None:
            assert tuple(data.shape) == full, (data.shape, full)
            self.data = jnp.asarray(data, self.dtype)
        else:
            self.data = jnp.zeros(full, self.dtype)

    # -- views -------------------------------------------------------------
    @property
    def halo(self) -> Tuple[int, ...]:
        """Per-axis halo width, ``(order,) * ndim``.

        Returns the number of ghost cells padded on EACH side of every
        spatial axis.  The scenario batch axis (if any) carries no halo.

        >>> grid(dtype=f32, shape=(8, 8), order=2).halo
        (2, 2)
        """
        return (self.order,) * len(self.shape)

    @property
    def _interior_idx(self):
        o = self.order
        idx = tuple(slice(o, o + s) for s in self.shape)
        return ((slice(None),) + idx) if self.batch else idx

    @property
    def interior(self) -> jnp.ndarray:
        """View of the halo-free interior, shape ``([batch,] *shape)``.

        Reading slices the ``order``-deep halo ring off ``data``; assigning
        writes a value of the same interior shape back (cast to the grid
        dtype), leaving the halo cells untouched.

        >>> g = grid(dtype=f32, shape=(4, 4), order=1)
        >>> g.interior = 2.0 * jnp.ones((4, 4))
        >>> (g.data.shape, float(g.interior[0, 0]), float(g.data[0, 0]))
        ((6, 6), 2.0, 0.0)
        """
        return self.data[self._interior_idx]

    @interior.setter
    def interior(self, value) -> None:
        self.data = self.data.at[self._interior_idx].set(
            jnp.asarray(value, self.dtype))

    # -- init helpers --------------------------------------------------------
    def randomize(self, seed: int = 0, scale: float = 1.0) -> "grid":
        """Fill the interior with ``scale`` × standard-normal noise.

        Args:
            seed: ``numpy.random.default_rng`` seed, so initial conditions
                are reproducible across runs and backends.
            scale: multiplier applied to the draws.

        Returns this grid (chainable):

        >>> g = grid(dtype=f32, shape=(8, 8), order=1).randomize(7)
        >>> bool(jnp.any(g.interior != 0.0))
        True
        """
        rng = np.random.default_rng(seed)
        shape = ((self.batch,) + self.shape) if self.batch else self.shape
        vals = scale * rng.standard_normal(shape)
        self.interior = np.asarray(vals, dtype=np.dtype(self.dtype))
        return self

    def copy(self) -> "grid":
        """Shallow copy: new ``grid`` sharing this one's (immutable) buffer.

        Backends never mutate ``data`` in place (jax arrays are immutable;
        runs assign fresh buffers), so a copy taken before a launch
        preserves the initial state for a reference run:

        >>> a = grid(dtype=f32, shape=(4, 4), order=1).randomize(0)
        >>> b = a.copy()
        >>> a.data = a.data + 1.0   # leaves b.data untouched
        >>> float(jnp.max(jnp.abs(a.data - b.data)))
        1.0
        """
        g = grid.__new__(grid)
        g.shape, g.order, g.dtype = self.shape, self.order, self.dtype
        g.batch = self.batch
        g.data = self.data
        return g

    def __repr__(self):
        b = f", batch={self.batch}" if self.batch else ""
        return (f"st.grid(shape={self.shape}, order={self.order}, "
                f"dtype={self.dtype}{b})")


# --------------------------------------------------------------------------
# kernel
# --------------------------------------------------------------------------
class Kernel:
    """A parsed stencil kernel: the object ``@st.kernel`` returns.

    Holds the kernel's ``ir`` (:class:`repro.core.ir.StencilIR` — grid/
    scalar params and the update expression), its static analysis in
    ``info`` (dimensionality, stencil ``shape``/``order``, flops per
    point, bytes moved), and a per-(backend, shapes) compilation cache.
    Pass it to ``st.map``/``st.timeloop``/``st.differentiable_timeloop``;
    it is not called directly.
    """

    def __init__(self, fn: Callable):
        self.fn = fn
        self.name = fn.__name__
        t0 = time.perf_counter()
        self.ir: _ir.StencilIR = _frontend.parse_kernel(fn)
        self.frontend_time = time.perf_counter() - t0
        _analysis.check_read_after_write(self.ir)
        self.info: _analysis.StencilInfo = _analysis.analyze(self.ir)
        self._cache: Dict = {}

    def __repr__(self):
        i = self.info
        return (f"<st.kernel {self.name}: {i.ndim}D {i.shape} order={i.order} "
                f"flops/pt={i.flops_per_point}>")


def kernel(fn: Callable) -> Kernel:
    """Decorator parsing a Python stencil function into a :class:`Kernel`.

    The body must be pure ``v.at(dx, dy, ...).set(expr)`` assignments over
    grid parameters (annotated ``st.grid``) and scalar parameters
    (``st.f32``/``st.i32``…), with relative offsets bounded by each grid's
    ``order`` (paper Table 1).  Parsing happens once at decoration time
    via the AST — the function itself never executes::

        @st.kernel
        def star2d1r(u: st.grid, v: st.grid):
            v.at(0, 0).set(0.5 * u.at(0, 0)
                           + 0.125 * (u.at(-1, 0) + u.at(1, 0))
                           + 0.125 * (u.at(0, -1) + u.at(0, 1)))

    Returns the :class:`Kernel` (so ``star2d1r.info.order == 1``).  Note:
    the source must be on disk (``inspect.getsource``) — kernels cannot be
    defined inside ``python -c`` strings or a REPL without a file.
    """
    return Kernel(fn)


def target(fn: Callable) -> Callable:
    """Decorator marking a driver function for ``st.launch``.

    A target is plain Python orchestrating ``st.map``/``st.timeloop``
    calls over grids (paper Listing 1's ``run``).  The decorator only tags
    the function — ``st.launch(backend=...)(run)(u, v, 10)`` supplies the
    backend/mesh context its stencil calls pick up.  Returns ``fn``.
    """
    fn._is_stencil_target = True
    return fn


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Backend:
    """Base class for backend selectors (``st.xla``/``st.pallas``/
    ``st.distributed``).

    A backend is an immutable value object: it names a lowering path and
    carries its knobs, and is hashed into compilation-cache keys — it
    holds no runtime state.  Instantiate a concrete subclass and pass it
    to ``st.launch(backend=...)`` or ``st.differentiable_timeloop(...,
    backend=...)``.
    """
    kind: str = "xla"

    def cache_key(self):
        """Hashable tuple identifying this configuration for compilation
        caches (every knob participates; subclasses with non-astuple-able
        fields override)."""
        return dataclasses.astuple(self)


@dataclasses.dataclass(frozen=True)
class xla(Backend):
    """Pure-``jax.numpy`` lowering compiled by XLA (the portable baseline).

    No knobs: stencils become shifted-slice arithmetic on the full grid
    buffer and XLA fuses the window.  Works on any jax platform and is
    the reference the other backends are validated against.
    """
    kind: str = "xla"


@dataclasses.dataclass(frozen=True)
class pallas(Backend):
    """TPU Pallas backend.  ``template`` per paper Table 2; ``block`` is the
    BlockSpec tile (the paper's Dx/Dy/Dz knobs); ``mem_type`` selects the
    streaming-dim storage for 2.5D templates ('registers' → unrolled VREG
    window, 'vmem' → VMEM scratch window, None → shape-directed default:
    star→registers, box→vmem, mirroring the paper's auto choice);
    ``interpret`` runs the kernel body in Python on CPU for validation.

    ``time_block=k`` enables in-kernel temporal blocking on the fused
    time-loop path (``st.timeloop``): each kernel invocation fetches a
    k·h-deep halo window per grid, advances k leapfrog steps in VMEM, and
    writes only the final interiors back (double-buffered) — per k steps
    each advanced grid costs one expanded-window read, one destination
    fetch and one block write instead of a read+write per step, an
    asymptotically ~k× HBM-traffic cut (small depths can lose to the halo
    growth; the autotuner measures).  Requires k·h ≤ the block extent on
    every axis (the default block geometry grows to fit) and a ``swap``
    pair on the timeloop."""
    kind: str = "pallas"
    template: str = "gmem"
    block: Optional[Tuple[int, ...]] = None
    mem_type: Optional[str] = None
    prefetch: bool = False
    interpret: bool = True  # CPU container: interpret by default
    time_block: int = 1

    def __post_init__(self):
        if self.template not in ("gmem", "smem", "f4", "shift", "unroll", "semi"):
            raise ValueError(f"unknown template {self.template!r}")
        if int(self.time_block) < 1:
            raise ValueError("time_block must be >= 1")


def tpu(**kw) -> pallas:
    """Alias for :class:`pallas` (paper naming: the TPU backend).

    ``st.tpu(template="smem", block=(256, 256))`` ≡
    ``st.pallas(template="smem", block=(256, 256))``.
    """
    return pallas(**kw)


def cuda(computeCapability: str = "", threadsPerBlock: Optional[Tuple[int, ...]] = None,
         template: str = "gmem", **kw) -> pallas:
    """Paper-compat alias: Listing 1's ``st.cuda(...)`` maps onto the Pallas
    backend (threadsPerBlock → BlockSpec block)."""
    del computeCapability
    return pallas(template=template, block=threadsPerBlock, **kw)


@dataclasses.dataclass(frozen=True)
class distributed(Backend):
    """shard_map domain decomposition across a device mesh.

    ``grid_axes`` maps stencil-grid axes to mesh axis names, e.g.
    ('data', 'model') splits axes 0,1 of the domain.  ``inner`` is the
    per-shard backend.  Halo exchange via ppermute; see core/distributed.py.

    ``time_steps`` > 1 enables overlapped tiling (paper §3 / time skewing
    at pod level): ONE k·h-wide halo exchange covers k kernel applications,
    trading a thin shell of redundant compute for 1/k the exchange rounds.
    Requires ``swap`` — the (older, newer) grid pair rotated between
    applications (the leapfrog buffer swap).

    Under the fused engine (``st.timeloop``) the whole fusion window runs
    as ONE shard_map'd program and ``time_steps`` (× a pallas ``inner``'s
    ``time_block``) sets only the exchange *depth* within it: a window of
    ``fuse_steps`` decomposes into ⌊w/k⌋ depth-k exchange groups plus a
    remainder group inside the same ``lax.fori_loop``.  The depth must
    satisfy k·h ≤ local shard extent; the window itself is unbounded.
    ``overlap`` there selects the deep-interior pre-pass that hides the
    ppermute latency behind compute (exchange geometry: core/halo.py).
    """
    kind: str = "distributed"
    grid_axes: Tuple[Optional[str], ...] = ("data",)
    inner: Backend = dataclasses.field(default_factory=xla)
    overlap: bool = True
    time_steps: int = 1
    swap: Optional[Tuple[str, str]] = None

    def cache_key(self):
        """Cache key flattening the nested ``inner`` backend (plain
        ``astuple`` would recurse into the dataclass and lose its type)."""
        return ("distributed", self.grid_axes, self.inner.cache_key(),
                self.overlap, self.time_steps, self.swap)


# --------------------------------------------------------------------------
# launch context + profiler
# --------------------------------------------------------------------------
class _Ctx(threading.local):
    def __init__(self):
        self.backend: Backend = xla()
        self.mesh = None
        self.profile: Dict[str, float] = {}
        self.active = False
        self.fuse_steps: Optional[int] = None
        self.time_block: Optional[int] = None
        self.autotune: Optional[Dict[str, object]] = None

    def add(self, phase: str, dt: float):
        self.profile[phase] = self.profile.get(phase, 0.0) + dt


_CTX = _Ctx()


@dataclasses.dataclass
class LaunchResult:
    """What a launched target returns.

    ``value`` is the target function's own return value; ``profile`` maps
    phase names to accumulated seconds — ``codegen`` (trace + lower),
    ``comp`` (XLA compile), ``kernel`` (device execution, blocked until
    ready) and ``total`` (wall clock for the whole launch).
    """
    value: object
    profile: Dict[str, float]


# --------------------------------------------------------------------------
# map — apply a kernel over a region
# --------------------------------------------------------------------------
class _MapCall:
    def __init__(self, begin=None, end=None, e=None):
        # syntax sugar (paper §4.2): map(e=u.shape) loops the whole interior
        if e is not None:
            begin = tuple(0 for _ in e)
            end = tuple(e)
        self.begin, self.end = begin, end

    def __call__(self, k: Kernel):
        def apply(*args):
            return _apply_kernel(k, args, self.begin, self.end)
        return apply


def map(begin=None, end=None, e=None) -> _MapCall:  # noqa: A001 (paper name)
    """Apply a kernel over an interior region (paper §4.2's ``map``).

    ``st.map(e=u.shape)(star2d1r)(u, v)`` sweeps the whole interior;
    ``st.map(begin=(8, 0), end=(16, 64))`` restricts the update to a
    sub-box (half-open per-axis bounds in interior coordinates — cells
    outside keep their old values).  The returned applicator binds
    positional args per the kernel signature (grids first, then scalars),
    runs one compiled application, and writes results back into the
    output grids' ``.data``.  Inside ``st.launch`` the context backend
    applies; standalone calls use ``st.xla()``.  For time stepping prefer
    ``st.timeloop`` — per-step ``map`` calls sync with the host every
    application.
    """
    return _MapCall(begin=begin, end=end, e=e)


def _bind_args(k: Kernel, args):
    """Split positional args into (grids dict, scalars dict) per the kernel
    signature, checking types and interior-shape consistency."""
    grids: Dict[str, grid] = {}
    scalars: Dict[str, object] = {}
    gi = 0
    for name in k.ir.grid_params:
        g = args[gi]
        if not isinstance(g, grid):
            raise TypeError(f"argument {gi} for '{name}' must be st.grid")
        grids[name] = g
        gi += 1
    for name, _dt in k.ir.scalar_params:
        scalars[name] = args[gi]
        gi += 1
    if gi != len(args):
        raise TypeError(f"{k.name} expects {gi} args, got {len(args)}")

    interior = next(iter(grids.values())).shape
    for g in grids.values():
        if g.shape != interior:
            raise ValueError("all grids in one map must share interior shape")
    batches = {g.batch for g in grids.values()}
    if len(batches) > 1:
        raise ValueError(
            f"all grids must share the scenario batch dimension "
            f"(got {sorted(b or 0 for b in batches)})")
    return grids, scalars


def _apply_kernel(k: Kernel, args, begin, end):
    grids, scalars = _bind_args(k, args)
    if next(iter(grids.values())).batch:
        raise ValueError("st.map does not support batched grids; use "
                         "st.timeloop(..., batch=B)")
    interior = next(iter(grids.values())).shape

    region = None
    if begin is not None:
        region = tuple((int(b), int(e)) for b, e in zip(begin, end))
        if region == tuple((0, s) for s in interior):
            region = None  # whole-interior sugar (paper's map(e=u.shape))

    backend = _CTX.backend if _CTX.active else xla()
    key = (backend.cache_key(), tuple(sorted((n, g.shape, g.order, str(g.dtype))
                                             for n, g in grids.items())), region)
    entry = k._cache.get(key)
    if entry is None:
        t0 = time.perf_counter()
        entry = _build_callable(k, backend, grids, region)
        _CTX.add("codegen", time.perf_counter() - t0)
        k._cache[key] = entry

    arrays = {n: g.data for n, g in grids.items()}
    t0 = time.perf_counter()
    out = entry(arrays, scalars)
    jax.block_until_ready(out)
    _CTX.add("kernel", time.perf_counter() - t0)
    for name in k.ir.output_grids():
        grids[name].data = out[name]
    return None


# --------------------------------------------------------------------------
# timeloop — fused time stepping (kernel application + buffer swap)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TimeloopResult:
    """Execution report returned by a ``st.timeloop`` application.

    ``steps`` is the number of kernel applications requested; ``fuse_steps``
    the fusion-window size that actually ran (after clamping to the loop
    length); ``windows`` the number of compiled-program invocations
    (``ceil(steps / fuse_steps)``); ``seconds`` the wall-clock time of the
    loop body including device sync.
    """
    steps: int
    fuse_steps: int
    windows: int
    seconds: float

    @property
    def steps_per_s(self) -> float:
        """Time-step throughput, ``steps / seconds`` (inf when untimed)."""
        return self.steps / self.seconds if self.seconds > 0 else float("inf")


class _TimeloopCall:
    def __init__(self, steps: int, swap=None, fuse_steps=None, between=None,
                 batch: int = 0):
        self.steps = int(steps)
        self.swap = tuple(swap) if swap is not None else None
        self.fuse_steps = fuse_steps
        self.between = between
        self.batch = int(batch)

    def __call__(self, k: Kernel):
        def apply(*args) -> TimeloopResult:
            return _run_timeloop(k, args, self)
        return apply


def timeloop(steps: int, swap=None, fuse_steps: Optional[int] = None,
             between=None, batch: int = 0) -> _TimeloopCall:
    """Fused time stepping: ``steps`` applications of the kernel plus the
    leapfrog buffer swap, traced once and executed inside a single compiled
    program per fusion window (paper-style time-to-solution execution;
    see ``core/timeloop.py``)::

        @st.target
        def run(u: st.grid, v: st.grid, iters: st.i32):
            st.timeloop(iters, swap=("v", "u"))(star2d1r)(u, v)

    ``swap`` names the grid pair whose buffers rotate after every step (the
    pair must contain the kernel's output grid).  ``fuse_steps`` is the
    fusion-window size: the host syncs (and the optional ``between(t,
    grids)`` hook runs) only every ``fuse_steps`` steps.  Default: fuse the
    whole loop, or the enclosing ``st.launch(..., fuse_steps=K)`` value.
    Equivalent to the per-step ``st.map`` loop up to float-accumulation
    order (identical when fuse_steps=1).

    ``batch=B`` advances B independent scenarios (grids built with
    ``st.grid(..., batch=B)``, scalar params passed as floats or ``(B,)``
    arrays) in one compiled program — the per-step kernel is vmapped over
    the leading scenario axis inside the fused loop.  Defaults to the
    grids' own batch dimension when they carry one.
    """
    return _TimeloopCall(steps, swap=swap, fuse_steps=fuse_steps,
                         between=between, batch=batch)


def _run_timeloop(k: Kernel, args, call: _TimeloopCall) -> TimeloopResult:
    from . import timeloop as _tl

    grids, scalars = _bind_args(k, args)
    interior = next(iter(grids.values())).shape
    grid_batch = next(iter(grids.values())).batch or 0
    if call.batch and grid_batch and call.batch != grid_batch:
        raise ValueError(
            f"st.timeloop(batch={call.batch}) but grids carry "
            f"batch={grid_batch}")
    if call.batch and not grid_batch:
        raise ValueError(
            f"st.timeloop(batch={call.batch}) requires grids built with "
            f"st.grid(..., batch={call.batch})")
    batch = call.batch or grid_batch
    backend = _CTX.backend if _CTX.active else xla()
    mesh = _CTX.mesh if _CTX.active else None
    swap = _tl.normalize_swap(k.ir, call.swap)

    at_cfg = _CTX.autotune if _CTX.active else None
    tuned_fuse = None
    if (at_cfg is not None and swap is not None and not batch
            and call.steps > 0 and backend.kind != "distributed"):
        # st.launch(autotune=...): pick the backend (and default fusion
        # window) via the two-stage cost-model search.  The measurement
        # launches inside tune() run under their own _Launcher, whose
        # default autotune=None stops recursion.
        from . import autotune as _at
        tuned = _at.tune(
            k, grids, iters=int(at_cfg.get("iters", 1)),
            space=at_cfg.get("space"), swap=swap,
            steps=min(call.steps, int(at_cfg.get("steps", 16))),
            fuse_space=at_cfg.get("fuse_space", (1, 4, 16)),
            time_block_space=at_cfg.get("time_block_space", (1, 2, 4)),
            cache_dir=at_cfg.get("cache_dir"),
            top_k=at_cfg.get("top_k", 3),
            cost_model=at_cfg.get("cost_model"),
            # distributed candidates in a custom space are priced and
            # measured on the launch mesh
            mesh=mesh)
        backend = tuned.backend
        tuned_fuse = tuned.fuse_steps
    tb = _CTX.time_block if _CTX.active else None
    if tb is not None:
        # launch-level override of the in-kernel temporal-blocking depth
        if backend.kind == "pallas":
            backend = dataclasses.replace(backend, time_block=int(tb))
        elif (backend.kind == "distributed"
              and getattr(backend.inner, "kind", None) == "pallas"):
            backend = dataclasses.replace(
                backend, inner=dataclasses.replace(backend.inner,
                                                   time_block=int(tb)))
        elif int(tb) != 1:
            # silently running without blocking would let a user believe
            # the depth is active while measuring the plain fused loop
            raise ValueError(
                f"time_block={tb} requires a pallas backend (or a "
                f"distributed backend with a pallas inner); got "
                f"'{backend.kind}'")
    fuse = call.fuse_steps
    if fuse is None and _CTX.active:
        fuse = _CTX.fuse_steps
    if fuse is None:
        fuse = tuned_fuse        # autotuned window, unless overridden
    if fuse is not None:
        fuse = max(1, int(fuse))

    key = ("timeloop", backend.cache_key(),
           tuple(sorted((n, g.shape, g.order, str(g.dtype))
                        for n, g in grids.items())),
           swap, id(mesh) if mesh is not None else None, batch)
    engine = k._cache.get(key)
    if engine is None:
        t0 = time.perf_counter()
        halos = {n: g.halo for n, g in grids.items()}
        engine = _tl.TimeloopEngine(
            k.ir, halos, interior, backend, swap=swap, mesh=mesh,
            profile_cb=_CTX.add if _CTX.active else None, batch=batch)
        _CTX.add("codegen", time.perf_counter() - t0)
        k._cache[key] = engine
    # clamp the window to the loop length; report the size that actually
    # runs.  Temporal depth (time_block / time_steps) never alters the
    # window — the between-hook cadence is honored exactly via in-window
    # decomposition on every backend
    fuse = engine.window_for(call.steps, fuse)

    def between_arrays(t, arrays):
        # surface current state to the user hook via the grid objects
        for n, g in grids.items():
            g.data = arrays[n]
        call.between(t, grids)
        return {n: g.data for n, g in grids.items()}

    arrays = {n: g.data for n, g in grids.items()}
    t0 = time.perf_counter()
    # window_for is idempotent, so the reported window can be passed back
    arrays = engine.run(arrays, scalars, call.steps, fuse,
                        between_arrays if call.between else None)
    seconds = time.perf_counter() - t0
    for n, g in grids.items():
        g.data = arrays[n]
    return TimeloopResult(
        steps=call.steps, fuse_steps=fuse,
        windows=-(-call.steps // fuse) if call.steps else 0,
        seconds=seconds)


def differentiable_timeloop(k: Kernel, *args,
                            steps: int,
                            swap=None,
                            fuse_steps: Optional[int] = None,
                            between=None,
                            domain_mask=None,
                            step_limits=None,
                            checkpoint_stride: Optional[int] = None,
                            backend=None,
                            mesh=None):
    """Differentiable fused time stepping (the adjoint wave propagator).

    Takes the SAME positional arguments a ``k(u, v, dt, st.timeloop(...))``
    call would (grids then scalars) and returns a PURE function

        fn(arrays: dict[str, jnp.ndarray], scalars: dict | None) -> dict

    computing ``steps`` fused applications of the kernel (+ leapfrog
    ``swap`` rotation, ``between`` hook, optional serving masks) exactly
    like ``st.timeloop`` — but reverse-mode differentiable under
    ``jax.grad``/``jax.vjp``, with O(√steps) checkpointed recomputation
    instead of O(steps) stored residuals (``core/adjoint.py``).  Gradients
    flow to every grid array (initial wavefields and coefficient grids
    such as a velocity model) and every float scalar; batched grids
    differentiate per-scenario.

    The positional args fix shapes/dtypes and provide defaults:
    ``fn.arrays`` / ``fn.scalars`` hold the bound initial values, and
    ``fn()`` runs them as-is.  ``fn.schedule`` reports the window/
    checkpoint plan.  ``between`` must be a pure traceable hook
    ``between(t, grids) -> None`` mutating ``g.data`` with jnp ops (e.g.
    source injection); it runs at window boundaries, so pass
    ``fuse_steps=1`` for a per-step cadence.  Backend/mesh come from the
    ``backend=`` / ``mesh=`` keywords, falling back to the enclosing
    ``st.launch`` context (default xla).  With
    ``backend=st.distributed(...), mesh=...`` the forward windows run as
    shard_mapped programs on the mesh and the backward pass pulls
    cotangents through each window's own reverse-``ppermute`` shard_map
    program — gradients reach sharded velocity grids and per-scenario
    scalars without ever gathering the wavefield.  The engine is built
    with ``differentiable=True`` — no buffer donation (donated window
    inputs cannot be VJP residuals), cached separately from the forward
    engine.

    Example::

        fn = st.differentiable_timeloop(
            k, u, v, c, dt, steps=200, swap=("v", "u"),
            backend=st.distributed(grid_axes=("data", None)),
            mesh=jax.make_mesh((8,), ("data",)))
        value, grads = jax.value_and_grad(
            lambda a: jnp.sum(fn(a)["v"] ** 2))(fn.arrays)
    """
    from . import adjoint as _adj
    from . import timeloop as _tl

    grids, scalars = _bind_args(k, args)
    interior = next(iter(grids.values())).shape
    batch = next(iter(grids.values())).batch or 0
    if backend is None:
        backend = _CTX.backend if _CTX.active else xla()
    if mesh is None:
        mesh = _CTX.mesh if _CTX.active else None
    swap = _tl.normalize_swap(k.ir, tuple(swap) if swap is not None else None)

    key = ("difftimeloop", backend.cache_key(),
           tuple(sorted((n, g.shape, g.order, str(g.dtype))
                        for n, g in grids.items())),
           swap, id(mesh) if mesh is not None else None, batch)
    engine = k._cache.get(key)
    if engine is None:
        halos = {n: g.halo for n, g in grids.items()}
        engine = _tl.TimeloopEngine(
            k.ir, halos, interior, backend, swap=swap, mesh=mesh,
            batch=batch, differentiable=True)
        k._cache[key] = engine

    between_arrays = None
    if between is not None:
        def between_arrays(t, arrays):
            # same grid-object surface as st.timeloop's hook — but traced,
            # so the hook must be pure jnp code on g.data
            for n, g in grids.items():
                g.data = arrays[n]
            between(t, grids)
            return {n: g.data for n, g in grids.items()}

    run = _adj.differentiable_run(
        engine, steps, fuse_steps, between_arrays,
        domain_mask=domain_mask, step_limits=step_limits,
        checkpoint_stride_windows=checkpoint_stride)

    def fn(arrays=None, scal=None):
        if arrays is None:
            arrays = {n: g.data for n, g in grids.items()}
        if scal is None:
            scal = scalars
        return run(arrays, scal)

    fn.arrays = {n: g.data for n, g in grids.items()}
    fn.scalars = dict(scalars)
    fn.schedule = run.schedule
    fn.engine = engine
    return fn


def _build_callable(k: Kernel, backend: Backend, grids: Dict[str, grid], region):
    halos = {n: g.halo for n, g in grids.items()}
    interior = next(iter(grids.values())).shape
    if backend.kind == "xla":
        fn = _lowering.lower_jax(k.ir, halos, interior, region)
        jitted = jax.jit(fn)
    elif backend.kind == "pallas":
        from repro.kernels.stencil import codegen as _codegen
        fn = _codegen.lower_pallas(k.ir, halos, interior, region, backend)
        jitted = jax.jit(fn)
    elif backend.kind == "distributed":
        from . import distributed as _dist
        fn = _dist.lower_distributed(k.ir, halos, interior, region,
                                     backend, _CTX.mesh)

        def run_dist(arrays, scalars):
            return fn(arrays, scalars)
        return run_dist
    else:
        raise ValueError(backend.kind)

    # explicit AOT compile so the profiler separates comp from kernel time
    abstract_arrays = {n: jax.ShapeDtypeStruct(g.data.shape, g.dtype)
                       for n, g in grids.items()}
    abstract_scalars = {n: jax.ShapeDtypeStruct((), jnp.float32)
                        for n, _ in k.ir.scalar_params}
    t0 = time.perf_counter()
    try:
        compiled = jitted.lower(abstract_arrays, abstract_scalars).compile()
        _CTX.add("comp", time.perf_counter() - t0)

        def run(arrays, scalars):
            scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
            return compiled(arrays, scal)
        return run
    except Exception:
        # fall back to on-demand jit (e.g. scalar dtype mismatch)
        _CTX.add("comp", time.perf_counter() - t0)

        def run(arrays, scalars):
            scal = {n: jnp.asarray(v, jnp.float32) for n, v in scalars.items()}
            return jitted(arrays, scal)
        return run


# --------------------------------------------------------------------------
# launch
# --------------------------------------------------------------------------
class _Launcher:
    def __init__(self, backend: Backend, mesh=None, profile: bool = True,
                 fuse_steps: Optional[int] = None,
                 time_block: Optional[int] = None,
                 autotune: Optional[Dict[str, object]] = None):
        self.backend, self.mesh, self.profile = backend, mesh, profile
        self.fuse_steps = fuse_steps
        self.time_block = time_block
        self.autotune = autotune

    def __call__(self, tgt: Callable):
        def run(*args, **kw) -> LaunchResult:
            prev = (_CTX.backend, _CTX.mesh, _CTX.profile, _CTX.active,
                    _CTX.fuse_steps, _CTX.time_block, _CTX.autotune)
            _CTX.backend, _CTX.mesh = self.backend, self.mesh
            _CTX.profile, _CTX.active = {}, True
            _CTX.fuse_steps = self.fuse_steps
            _CTX.time_block = self.time_block
            _CTX.autotune = self.autotune
            t0 = time.perf_counter()
            try:
                value = tgt(*args, **kw)
            finally:
                prof = _CTX.profile
                prof["total"] = time.perf_counter() - t0
                (_CTX.backend, _CTX.mesh, _CTX.profile, _CTX.active,
                 _CTX.fuse_steps, _CTX.time_block, _CTX.autotune) = prev
            return LaunchResult(value=value, profile=prof)
        return run


def launch(backend: Backend = None, mesh=None, profile: bool = True,
           fuse_steps: Optional[int] = None,
           time_block: Optional[int] = None,
           autotune: bool = False,
           autotune_space: Optional[List] = None,
           autotune_cache: Optional[str] = None,
           autotune_top_k: Optional[int] = 3,
           autotune_steps: int = 16,
           autotune_iters: int = 1,
           autotune_fuse_space: Sequence[int] = (1, 4, 16),
           autotune_time_block_space: Sequence[int] = (1, 2, 4),
           autotune_cost_model=None) -> _Launcher:
    """Run a ``@st.target`` under ``backend``.  ``fuse_steps`` sets the
    default fusion-window size for any ``st.timeloop`` inside the target
    (per-step ``st.map`` loops are unaffected).  ``time_block`` overrides
    the pallas backend's in-kernel temporal-blocking depth for those
    timeloops (k leapfrog steps per kernel invocation; see st.pallas).

    ``autotune=True`` replaces the fixed ``backend`` for each
    ``st.timeloop`` with the winner of the two-stage cost-model search
    over ``autotune_space`` (see ``core/autotune.py``): all candidates
    are ranked by predicted cost, only the ``autotune_top_k`` cheapest
    are measured (``None`` → exhaustive), and results are cached
    in-process and — with ``autotune_cache`` — on disk.  The tuned
    fusion window applies unless ``fuse_steps`` (or the timeloop's own)
    overrides it; ``time_block`` still applies on top of the tuned
    backend.  Batched, distributed, and swap-less timeloops fall
    through to the fixed backend unchanged."""
    at_cfg = None
    if autotune:
        at_cfg = {"space": autotune_space, "cache_dir": autotune_cache,
                  "top_k": autotune_top_k, "steps": int(autotune_steps),
                  "iters": int(autotune_iters),
                  "fuse_space": tuple(autotune_fuse_space),
                  "time_block_space": tuple(autotune_time_block_space),
                  "cost_model": autotune_cost_model}
    return _Launcher(backend or xla(), mesh=mesh, profile=profile,
                     fuse_steps=fuse_steps, time_block=time_block,
                     autotune=at_cfg)
