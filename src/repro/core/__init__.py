"""repro.core — StencilPy-JAX: the paper's contribution as a JAX library.

``from repro.core import dsl as st`` gives the user-facing DSL (paper
Table 1); submodules: frontend (parser), ir, analysis, lowering (xla
backend), timeloop (fused time-stepping engine; ``st.pallas``'s
``time_block=k`` knob advances k leapfrog steps per kernel invocation
with expanded k·h halos), distributed (multi-chip halo exchange + pod
time skewing, composable with in-kernel time_block), suite (paper
Table 4 kernel suite), regions (PML decomposition), autotune (joint
template × block × fuse_steps × time_block search).
"""
from . import analysis, dsl, frontend, ir, lowering, suite, timeloop  # noqa: F401
