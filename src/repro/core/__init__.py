"""repro.core — StencilPy-JAX: the paper's contribution as a JAX library.

``from repro.core import dsl as st`` gives the user-facing DSL (paper
Table 1); submodules: frontend (parser), ir, analysis, lowering (xla
backend), timeloop (fused time-stepping engine), distributed (multi-chip
halo exchange), suite (paper Table 4 kernel suite), regions (PML
decomposition), autotune.
"""
from . import analysis, dsl, frontend, ir, lowering, timeloop  # noqa: F401
