"""First-class halo-exchange geometry for the distributed stencil runtime.

``HaloSpec`` lifts the exchange bookkeeping that used to live implicitly
inside ``core/distributed.py`` — pad widths, slab shapes, shrinking
per-step compute regions, global-boundary zero fill, redundant-shell
feasibility — into a frozen, directly testable object (modeled on xdsl's
``HaloExchangeDef``: each exchanged slab carries its offset, size, source
offset and neighbor direction).

Geometry of one depth-``k`` exchange group (overlapped tiling / time
skewing, paper §3 at pod level): each shard exchanges ONE wide halo and
then computes ``k`` kernel applications on regions shrinking by ``h_max``
per step.  The slab widths are

    swap pair        k·h_max          (uniform — the pair trades buffers
                                       between steps and must share layout)
    other grids      (k−1)·h_max + h_g  (per axis: deepest shell read)

Axes mapped to a mesh axis of size 1 and unmapped axes receive *zeros*
instead of a neighbor slab — the global zero grid-halo; shards at a mesh
boundary re-impose the same zeros on the cells beyond the global edge
between fused steps (``zero widths`` here, masking in the lowering).

A fusion window of ``w`` steps decomposes into ``w // k`` full-depth
groups plus one remainder group of depth ``w mod k`` (the same split as
``timeloop.window_parts``); ``window_collective_bytes`` prices exactly
that schedule — coefficients exchanged once per window at the full
depth, the swap pair once per group at the group's own depth — and is
cross-checked against ``hlo_analysis`` collective accounting of the
compiled program in ``benchmarks/distributed_stencil.py``.

``transpose()`` is the adjoint geometry: reverse-mode differentiation
turns every halo *receive* into a cotangent *send-back* — the transpose
of a ``ppermute`` is the ppermute with the inverted permutation, moving
the same slab the opposite way, and the slab lands as an *accumulation*
into the neighbor's edge region instead of an overwrite of a halo
region.  Slab shapes (and therefore ``window_collective_bytes``) are
identical to the forward spec; only direction and destination flip.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

__all__ = ["HaloExchange", "HaloSpec"]


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class HaloExchange:
    """One exchanged slab (xdsl ``HaloExchangeDef`` shape).

    ``offset`` is the slab origin in *local interior* coordinates (negative
    on the low side), ``size`` its shape in the axis-by-axis padded layout
    the lowering concatenates (axes below ``axis`` are already padded when
    this slab moves, so their extents include both halos), and
    ``source_offset`` the shift onto the neighbor's coordinates — the cells
    arrive from ``offset + source_offset`` on the ``neighbor`` side.

    ``accumulate`` marks an adjoint (transposed) exchange: the arriving
    slab is *added into* the destination region (cotangents from the
    neighbor's halo reads sum into the owning cells) instead of
    overwriting a halo region, exactly as the transpose of a gather is a
    scatter-add."""
    grid: str
    axis: int                       # grid axis being exchanged
    mesh_axis: str                  # mesh axis the neighbor lives on
    neighbor: int                   # -1: from the lower shard, +1: higher
    width: int                      # slab width along ``axis``
    size: Tuple[int, ...]
    offset: Tuple[int, ...]
    source_offset: Tuple[int, ...]
    accumulate: bool = False

    @property
    def elems(self) -> int:
        """Number of grid points in the slab (product of ``size``)."""
        return _prod(self.size)

    def nbytes(self, itemsize: int, batch: int = 1) -> int:
        """Bytes this slab moves: ``elems * itemsize``, times the scenario
        ``batch`` when the grids carry a leading batch axis (every scenario
        exchanges its own slab inside one collective)."""
        return self.elems * int(itemsize) * max(1, int(batch))

    def source_area(self) -> Tuple[Tuple[int, int], ...]:
        """(begin, end) per axis of the source region on the neighbor."""
        return tuple((o + s, o + s + sz) for o, s, sz in
                     zip(self.offset, self.source_offset, self.size))


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Exchange geometry of one depth-``k`` group of a distributed
    stencil: built once from pure geometry (no live mesh, no devices), so
    every derived quantity is directly assertable in tests."""
    halos: Tuple[Tuple[str, Tuple[int, ...]], ...]   # grid → stencil halo
    grid_axes: Tuple[Optional[str], ...]             # grid axis → mesh axis
    interior_shape: Tuple[int, ...]
    mesh_shape: Tuple[Tuple[str, int], ...]          # mesh axis → size
    depth: int                                       # k: steps per exchange
    swap: Optional[Tuple[str, str]]
    h_max: int
    local_shape: Tuple[int, ...]
    ext: Tuple[Tuple[str, Tuple[int, ...]], ...]     # grid → pad widths
    reverse: bool = False                            # adjoint direction

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, halos: Mapping[str, Sequence[int]],
              grid_axes: Sequence[Optional[str]],
              interior_shape: Sequence[int],
              mesh_shape: Mapping[str, int],
              depth: int = 1,
              swap: Optional[Tuple[str, str]] = None) -> "HaloSpec":
        """Validate and derive the geometry.  Raises ``ValueError`` for an
        indivisible decomposition, a depth the local extent cannot carry
        (k·h_max > local), or a swap pair that is not a grid."""
        grid_axes = tuple(grid_axes)
        interior_shape = tuple(int(s) for s in interior_shape)
        ndim = len(interior_shape)
        if len(grid_axes) != ndim:
            raise ValueError(f"grid_axes must have {ndim} entries "
                             f"(got {grid_axes})")
        mesh_shape = {str(a): int(n) for a, n in dict(mesh_shape).items()}
        halos = {g: tuple(int(h) for h in hs) for g, hs in halos.items()}
        depth = int(depth)
        if depth < 1:
            raise ValueError("exchange depth must be >= 1")
        for ax, m in enumerate(grid_axes):
            if m is None:
                continue
            if m not in mesh_shape:
                raise ValueError(f"grid axis {ax} maps to unknown mesh "
                                 f"axis {m!r} (mesh has {sorted(mesh_shape)})")
            if interior_shape[ax] % mesh_shape[m]:
                raise ValueError(
                    f"domain axis {ax} ({interior_shape[ax]}) not divisible "
                    f"by mesh axis '{m}' ({mesh_shape[m]})")
        local = tuple(
            s // (mesh_shape[m] if m else 1)
            for s, m in zip(interior_shape, grid_axes))
        h_max = max((h for hs in halos.values() for h in hs), default=0)
        if depth > 1:
            if swap is None:
                raise ValueError("exchange depth > 1 requires a swap pair")
            if h_max == 0:
                raise ValueError("time skewing needs a nonzero stencil halo")
        if swap is not None:
            for g in swap:
                if g not in halos:
                    raise ValueError(f"swap grid {g!r} is not a grid")
        # decomposed axes exchange (k−1)·h_max + h_g wide slabs; the swap
        # pair must share geometry (they trade buffers between steps) →
        # both get the uniform k·h_max
        ext = {g: tuple((depth - 1) * h_max + hs[ax] for ax in range(ndim))
               for g, hs in halos.items()}
        for g in (swap or ()):
            ext[g] = (depth * h_max,) * ndim
        for ax, m in enumerate(grid_axes):
            if m and depth * h_max > local[ax]:
                raise ValueError(
                    f"k·h halo ({depth}·{h_max}) exceeds local extent "
                    f"{local[ax]} on axis {ax}; reduce time_steps or the "
                    f"mesh split")
        return cls(halos=tuple(sorted(halos.items())),
                   grid_axes=grid_axes,
                   interior_shape=interior_shape,
                   mesh_shape=tuple(sorted(mesh_shape.items())),
                   depth=depth, swap=tuple(swap) if swap else None,
                   h_max=h_max, local_shape=local,
                   ext=tuple(sorted(ext.items())))

    def with_depth(self, depth: int) -> "HaloSpec":
        """Same decomposition at another temporal depth (remainder groups)."""
        sub = HaloSpec.build(dict(self.halos), self.grid_axes,
                             self.interior_shape, dict(self.mesh_shape),
                             depth=depth, swap=self.swap)
        return dataclasses.replace(sub, reverse=self.reverse)

    def transpose(self) -> "HaloSpec":
        """The adjoint exchange geometry: same grids, widths, slab shapes
        and traffic, but every slab moves the *opposite* direction and
        lands as an accumulation into the neighbor's edge region (the
        reverse ``ppermute`` that is the transpose of the forward one).
        An involution: ``spec.transpose().transpose() == spec``.

        >>> s = HaloSpec.build({"u": (1, 1), "v": (1, 1)}, ("data", None),
        ...                    (8, 8), {"data": 2}, depth=1, swap=("v", "u"))
        >>> t = s.transpose()
        >>> t.exchange_bytes(4) == s.exchange_bytes(4)
        True
        >>> t.transpose() == s
        True
        """
        return dataclasses.replace(self, reverse=not self.reverse)

    # -- mappings ----------------------------------------------------------
    @property
    def grids(self) -> Tuple[str, ...]:
        """Grid names in the spec, sorted (the ``halos`` mapping's keys)."""
        return tuple(g for g, _ in self.halos)

    @property
    def ndim(self) -> int:
        """Number of spatial axes of the decomposed domain."""
        return len(self.interior_shape)

    def halo_of(self, grid: str) -> Tuple[int, ...]:
        """Per-axis stencil halo of one grid (the ``order``-derived widths
        the kernel reads, before any depth widening)."""
        return dict(self.halos)[grid]

    def ext_of(self, grid: str) -> Tuple[int, ...]:
        """Pad/exchange width per axis for one grid at this depth."""
        return dict(self.ext)[grid]

    def mesh_size(self, name: Optional[str]) -> int:
        """Shard count along mesh axis ``name`` (1 for ``None``/unknown —
        an unmapped grid axis behaves like a single-shard split)."""
        return dict(self.mesh_shape).get(name, 1) if name else 1

    def decomposed_axes(self) -> Tuple[int, ...]:
        """Grid-axis indices mapped to a mesh axis (in axis order)."""
        return tuple(ax for ax, m in enumerate(self.grid_axes) if m)

    def exchanged(self, ax: int) -> bool:
        """True when this axis moves real neighbor slabs (mapped to a mesh
        axis of size > 1); mapped size-1 axes and unmapped axes are
        zero-filled instead (the global zero grid-halo)."""
        m = self.grid_axes[ax]
        return bool(m) and self.mesh_size(m) > 1

    def padded_shape(self, grid: str) -> Tuple[int, ...]:
        """Local shard shape of one grid after the exchange pads both sides
        of every axis with its ``ext_of`` width (what the per-shard kernel
        actually sees, minus any scenario batch axis)."""
        e = self.ext_of(grid)
        return tuple(l + 2 * w for l, w in zip(self.local_shape, e))

    # -- slabs -------------------------------------------------------------
    def exchanges(self, grids: Optional[Sequence[str]] = None
                  ) -> Tuple[HaloExchange, ...]:
        """Every slab one exchange round at this depth actually moves (both
        directions; zero-filled axes excluded).  Slab shapes follow the
        axis-by-axis pad order of the lowering: axes below the exchanged
        one are already halo-padded when its slab moves.

        On a ``reverse`` (transposed) spec each slab is the adjoint of the
        corresponding forward one: its destination is the forward slab's
        *source* region (the neighbor's edge cells whose values were read
        through the halo), its source is the forward destination (my halo
        region, now holding cotangents), the neighbor direction is
        inverted, and ``accumulate`` is set — same width, same shape,
        same bytes."""
        out = []
        for g in (grids if grids is not None else self.grids):
            e = self.ext_of(g)
            for ax in range(self.ndim):
                w = e[ax]
                if w == 0 or not self.exchanged(ax):
                    continue
                size = tuple(
                    w if a == ax
                    else (self.local_shape[a] + 2 * e[a] if a < ax
                          else self.local_shape[a])
                    for a in range(self.ndim))
                for nb in (-1, +1):
                    offset = tuple(
                        (-w if nb < 0 else self.local_shape[ax])
                        if a == ax else (-e[a] if a < ax else 0)
                        for a in range(self.ndim))
                    src = tuple(
                        (self.local_shape[ax] if nb < 0
                         else -self.local_shape[ax]) if a == ax else 0
                        for a in range(self.ndim))
                    if self.reverse:
                        # adjoint slab: land on the forward source region,
                        # pull from the forward destination, flip neighbor
                        offset = tuple(o + s for o, s in zip(offset, src))
                        src = tuple(-s for s in src)
                        nb = -nb
                    out.append(HaloExchange(
                        grid=g, axis=ax, mesh_axis=self.grid_axes[ax],
                        neighbor=nb, width=w, size=size, offset=offset,
                        source_offset=src, accumulate=self.reverse))
        return tuple(out)

    def zero_widths(self, grid: str) -> Tuple[int, ...]:
        """Per-axis width of the zero fill replacing a neighbor slab on
        axes that have no neighbor (unmapped, or mesh size 1).  Mapped
        edge shards additionally re-impose zeros of ``ext`` width beyond
        the global boundary between fused steps (masked in the lowering)."""
        e = self.ext_of(grid)
        return tuple(0 if self.exchanged(ax) else e[ax]
                     for ax in range(self.ndim))

    # -- per-step compute regions -----------------------------------------
    def step_region(self, i: int) -> Tuple[Tuple[int, int], ...]:
        """Compute region of sub-step ``i`` (0-based) of a depth-k group,
        in local interior coordinates: decomposed axes carry a redundant
        shell of (k−1−i)·h_max that shrinks to zero at the last step."""
        if not 0 <= i < self.depth:
            raise ValueError(f"step {i} outside depth {self.depth}")
        shell = (self.depth - 1 - i) * self.h_max
        return tuple(
            (-shell, self.local_shape[ax] + shell) if self.grid_axes[ax]
            else (0, self.local_shape[ax])
            for ax in range(self.ndim))

    def deep_interior(self) -> Tuple[Tuple[int, int], ...]:
        """The h_max-shrunk interior whose first-step update reads no
        exchanged cell — computable before the ppermutes resolve (the
        overlap pre-pass)."""
        return tuple(
            (self.h_max, self.local_shape[ax] - self.h_max)
            if self.grid_axes[ax] else (0, self.local_shape[ax])
            for ax in range(self.ndim))

    def boundary_bands(self) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
        """Step-0 regions outside the deep interior (two bands per
        decomposed axis, spanning the full step-0 extent on the others).
        Patched sequentially they exactly tile step_region(0) minus
        deep_interior() — overlapping corners recompute identical values."""
        r0 = self.step_region(0)
        bands = []
        for ax in self.decomposed_axes():
            lo = tuple((r0[a][0], self.h_max) if a == ax else r0[a]
                       for a in range(self.ndim))
            hi = tuple((self.local_shape[a] - self.h_max, r0[a][1])
                       if a == ax else r0[a] for a in range(self.ndim))
            bands.append(lo)
            bands.append(hi)
        return tuple(bands)

    def overlap_feasible(self) -> bool:
        """The pre-pass needs a nonempty deep interior on every decomposed
        axis (local > 2·h_max) and actual communication to hide — at least
        one axis moving real neighbor slabs (mesh size > 1)."""
        if self.h_max == 0 or not self.decomposed_axes():
            return False
        if not any(self.exchanged(ax) for ax in self.decomposed_axes()):
            return False
        return all(self.local_shape[ax] > 2 * self.h_max
                   for ax in self.decomposed_axes())

    # -- window schedule & traffic ----------------------------------------
    def group_depths(self, window: int) -> Tuple[Tuple[int, int], ...]:
        """(count, depth) exchange groups covering a ``window``-step fusion
        window: ``window // depth`` full groups plus one remainder group —
        the ``timeloop.window_parts`` split expressed as groups."""
        window = int(window)
        if window < 1:
            raise ValueError("window must be >= 1")
        m, r = divmod(window, self.depth)
        out = []
        if m:
            out.append((m, self.depth))
        if r:
            out.append((1, r))
        return tuple(out)

    def exchange_bytes(self, itemsize: int,
                       grids: Optional[Sequence[str]] = None,
                       batch: int = 1) -> int:
        """Bytes one exchange round at this depth moves per shard (the
        hlo_analysis convention: a collective-permute is charged its full
        result slab on every device)."""
        return sum(ex.nbytes(itemsize, batch)
                   for ex in self.exchanges(grids))

    def window_collective_bytes(self, window: int, itemsize: int,
                                batch: int = 1) -> int:
        """Per-shard collective bytes of one fused ``window``: coefficient
        grids are exchanged ONCE (at this spec's full depth — wide enough
        for every group); the swap pair once per group at the group's own
        depth.  Mirrors ``distributed.lower_distributed_window`` exactly —
        cross-checked against compiled-HLO collective accounting in
        ``benchmarks/distributed_stencil.py``."""
        sw = set(self.swap or ())
        coeffs = [g for g in self.grids if g not in sw]
        total = self.exchange_bytes(itemsize, coeffs, batch)
        for count, d in self.group_depths(window):
            sub = self if d == self.depth else self.with_depth(d)
            total += count * sub.exchange_bytes(itemsize, sorted(sw), batch)
        return total
