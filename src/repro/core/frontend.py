"""DSL frontend: parse ``@st.kernel`` Python functions into StencilIR.

Mirrors the paper's frontend layer (§4.2): the DSL is hosted in Python, type
hints are *required* on kernel parameters, and only the stencil constructs of
Table 1 (``at`` / ``at.set``) plus ordinary arithmetic are admitted.  Parsing
uses the stdlib ``ast`` module; errors are reported as ``StencilSyntaxError``
with source locations.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Dict, List, Tuple

from . import ir

_MATH_FNS = frozenset({"exp", "sqrt", "abs", "min", "max", "sin", "cos", "tanh"})

_GRID_ANNOTATIONS = frozenset({"grid"})
_SCALAR_ANNOTATIONS = frozenset({"f32", "f64", "bf16", "i32", "i64"})


class StencilSyntaxError(SyntaxError):
    pass


def _err(node: ast.AST, msg: str) -> StencilSyntaxError:
    return StencilSyntaxError(f"line {getattr(node, 'lineno', '?')}: {msg}")


def _annotation_name(node: ast.expr) -> str:
    """'st.grid' / 'st.f32' → 'grid' / 'f32' (module alias ignored)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    raise _err(node, "unsupported type annotation; use st.grid / st.f32 / st.i32")


def _const_int(node: ast.expr) -> int:
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -_const_int(node.operand)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    raise _err(node, "stencil offsets must be integer literals")


class _KernelParser:
    def __init__(self, fn_name: str, tree: ast.FunctionDef):
        self.fn_name = fn_name
        self.tree = tree
        self.grids: List[str] = []
        self.scalars: List[Tuple[str, str]] = []
        self.locals: Dict[str, bool] = {}
        self.ndim: int = -1

    # -- signature ---------------------------------------------------------
    def parse_signature(self) -> None:
        args = self.tree.args
        if args.kwonlyargs or args.vararg or args.kwarg or args.posonlyargs:
            raise _err(self.tree, "kernels take plain positional parameters only")
        for a in args.args:
            if a.annotation is None:
                raise _err(a, f"parameter '{a.arg}' needs a type hint "
                              "(st.grid or scalar st.f32/st.i32 ...)")
            ann = _annotation_name(a.annotation)
            if ann in _GRID_ANNOTATIONS:
                self.grids.append(a.arg)
            elif ann in _SCALAR_ANNOTATIONS:
                self.scalars.append((a.arg, ann))
            else:
                raise _err(a, f"unknown annotation '{ann}' on '{a.arg}'")

    # -- expressions -------------------------------------------------------
    def parse_expr(self, node: ast.expr) -> ir.Expr:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)):
                return ir.Const(float(node.value))
            raise _err(node, f"unsupported constant {node.value!r}")
        if isinstance(node, ast.Name):
            if node.id in self.locals:
                return ir.LocalRef(node.id)
            for name, _ in self.scalars:
                if name == node.id:
                    return ir.ScalarRef(node.id)
            if node.id in self.grids:
                raise _err(node, f"grid '{node.id}' must be read via .at(...)")
            raise _err(node, f"unknown name '{node.id}'")
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.USub):
                return ir.Neg(self.parse_expr(node.operand))
            if isinstance(node.op, ast.UAdd):
                return self.parse_expr(node.operand)
            raise _err(node, "unsupported unary operator")
        if isinstance(node, ast.BinOp):
            ops = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*",
                   ast.Div: "/", ast.Pow: "**"}
            for a_ty, sym in ops.items():
                if isinstance(node.op, a_ty):
                    return ir.BinOp(sym, self.parse_expr(node.left),
                                    self.parse_expr(node.right))
            raise _err(node, "unsupported binary operator")
        if isinstance(node, ast.Call):
            return self.parse_call(node)
        raise _err(node, f"unsupported expression {ast.dump(node)[:60]}")

    def parse_call(self, node: ast.Call) -> ir.Expr:
        # u.at(dx, dy[, dz])  — grid tap
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "at" and \
                isinstance(f.value, ast.Name) and f.value.id in self.grids:
            offs = tuple(_const_int(a) for a in node.args)
            self._check_ndim(node, len(offs))
            return ir.Tap(f.value.id, offs)
        # whitelisted math functions: st.exp(x), exp(x), abs(x), ...
        fn_name = None
        if isinstance(f, ast.Attribute):
            fn_name = f.attr
        elif isinstance(f, ast.Name):
            fn_name = f.id
        if fn_name in _MATH_FNS:
            return ir.Call(fn_name, tuple(self.parse_expr(a) for a in node.args))
        raise _err(node, "unsupported call (only grid.at(...) and "
                         f"math fns {sorted(_MATH_FNS)} allowed)")

    def _check_ndim(self, node: ast.AST, n: int) -> None:
        if self.ndim == -1:
            self.ndim = n
        elif self.ndim != n:
            raise _err(node, f"inconsistent offset arity: {n} vs {self.ndim}")

    # -- statements --------------------------------------------------------
    def parse_body(self) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for stmt in self.tree.body:
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant) \
                    and isinstance(stmt.value.value, str):
                continue  # docstring
            if isinstance(stmt, ast.Assign):
                if len(stmt.targets) != 1 or not isinstance(stmt.targets[0], ast.Name):
                    raise _err(stmt, "local assignment must be 'name = expr'")
                name = stmt.targets[0].id
                expr = self.parse_expr(stmt.value)
                self.locals[name] = True
                out.append(ir.LocalDef(name, expr))
                continue
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
                out.append(self.parse_set(stmt.value))
                continue
            raise _err(stmt, "kernels may only contain local assignments and "
                             "grid.at(...).set(...) statements")
        if not any(isinstance(s, ir.Assign) for s in out):
            raise _err(self.tree, "kernel has no grid.at(...).set(...) update")
        return out

    def parse_set(self, node: ast.Call) -> ir.Assign:
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr == "set"):
            raise _err(node, "expected grid.at(...).set(expr)")
        at_call = f.value
        if not (isinstance(at_call, ast.Call)
                and isinstance(at_call.func, ast.Attribute)
                and at_call.func.attr == "at"
                and isinstance(at_call.func.value, ast.Name)
                and at_call.func.value.id in self.grids):
            raise _err(node, "expected grid.at(...).set(expr)")
        grid = at_call.func.value.id
        offs = tuple(_const_int(a) for a in at_call.args)
        self._check_ndim(node, len(offs))
        if any(o != 0 for o in offs):
            raise _err(node, "stencil updates must write the center point "
                             "(all .set offsets must be 0)")
        if len(node.args) != 1:
            raise _err(node, ".set takes exactly one expression")
        return ir.Assign(grid, offs, self.parse_expr(node.args[0]))


def parse_kernel(fn) -> ir.StencilIR:
    """Parse a Python function decorated with ``@st.kernel`` into StencilIR."""
    src = getattr(fn, "__stencil_source__", None)  # synthesized kernels
    if src is None:
        src = inspect.getsource(fn)
    src = textwrap.dedent(src)
    mod = ast.parse(src)
    fndefs = [n for n in mod.body if isinstance(n, ast.FunctionDef)]
    if len(fndefs) != 1:
        raise StencilSyntaxError("expected exactly one function definition")
    tree = fndefs[0]
    # strip decorators
    p = _KernelParser(fn.__name__, tree)
    p.parse_signature()
    body = p.parse_body()
    if p.ndim == -1:
        raise StencilSyntaxError("kernel contains no .at(...) accesses")
    return ir.StencilIR(
        name=fn.__name__,
        ndim=p.ndim,
        grid_params=tuple(p.grids),
        scalar_params=tuple(p.scalars),
        body=tuple(body),
    )
