import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count on first init).  512 placeholder host devices let jax.make_mesh build
# the production meshes; nothing is ever executed — every cell is
# .lower().compile() against ShapeDtypeStructs only.
import argparse           # noqa: E402
import gzip               # noqa: E402
import json               # noqa: E402
import time               # noqa: E402
import traceback          # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs, sharding  # noqa: E402
from repro.configs.shapes import SHAPES, applicable, input_specs  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.serving.serve_loop import make_serve_step  # noqa: E402
from repro.train import train_loop  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def _batch_shard_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def _mem_stats(compiled) -> Optional[Dict]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return None
    if m is None:
        return None
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        if hasattr(m, k):
            out[k] = int(getattr(m, k))
    # bytes resident per device during the step (args aliased with outputs
    # are counted once via alias subtraction)
    if out:
        out["per_device_total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0))
    return out


def _cost_stats(compiled) -> Optional[Dict]:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    if not isinstance(c, dict):
        return None
    keep = {}
    for k, v in c.items():
        if k in ("flops", "transcendentals", "bytes accessed") or \
                k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def make_prefill_step(cfg):
    from repro.models import layers as L

    def prefill(params, batch):
        hid, _aux = api.forward_hidden(cfg, params, batch)
        return L.unembed(params["embed"], hid[:, -1:], cfg)

    return prefill


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict] = None):
    """Lower one (arch × shape × mesh) cell; returns (lowered, meta)."""
    overrides = dict(overrides or {})
    n_mb_override = overrides.pop("n_microbatches", None)
    cfg = configs.get(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape)
    pshapes = api.param_shapes(cfg)
    pshard = sharding.param_shardings(cfg, mesh, pshapes)

    if shape.kind == "train":
        n_mb = n_mb_override or max(
            1, shape.global_batch // _batch_shard_size(mesh))
        tc = train_loop.TrainConfig(opt=OptConfig(), n_microbatches=n_mb)
        with mesh:
            lowered, _ = train_loop.compile_train_step(cfg, tc, mesh, specs)
        meta = {"step": "train_step", "n_microbatches": n_mb}
        return lowered, meta, mesh, cfg

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        bshard = sharding.batch_shardings(cfg, mesh, specs)
        B = shape.global_batch
        out_spec = sharding.resolve(("batch", None, "vocab"),
                                    (B, 1, cfg.vocab), mesh)
        jitted = jax.jit(fn, in_shardings=(pshard, bshard),
                         out_shardings=NamedSharding(mesh, out_spec))
        with mesh, sharding.use_activation_mesh(mesh):
            lowered = jitted.lower(pshapes, specs)
        return lowered, {"step": "prefill_step"}, mesh, cfg

    # decode
    step = make_serve_step(cfg, sample=True)
    cache_spec = specs["cache"]
    cshard = sharding.cache_shardings(cfg, mesh, cache_spec)
    B = shape.global_batch
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = NamedSharding(
        mesh, sharding.resolve(("batch", None), (B, 1), mesh))
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    key_shard = sharding.scalar_sharding(mesh)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, cshard, tok_shard, key_shard),
        out_shardings=(tok_shard, cshard),
        donate_argnums=(1,))
    with mesh, sharding.use_activation_mesh(mesh):
        lowered = jitted.lower(pshapes, cache_spec, tok_spec, key_spec)
    return lowered, {"step": "serve_step"}, mesh, cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False, overrides: Optional[Dict] = None,
             tag: str = "") -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "kind": shape.kind, "tag": tag}
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    try:
        t0 = time.perf_counter()
        lowered, meta, mesh, cfg2 = lower_cell(arch, shape_name, multi_pod,
                                               overrides)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        n_dev = mesh.size
        hlo = compiled.as_text()
        stats = hlo_analysis.analyze(hlo, n_dev)
        rec.update(meta)
        rec.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": _mem_stats(compiled),
            "cost": _cost_stats(compiled),
            "hlo_walk": hlo_analysis.summarize(stats),
            "model_params": api.param_count(cfg2),
            "active_params": api.active_param_count(cfg2),
            "hlo_len": len(hlo),
        })
        if save_hlo:
            os.makedirs(ARTIFACT_DIR, exist_ok=True)
            p = os.path.join(
                ARTIFACT_DIR,
                f"{arch}__{shape_name}__{mesh_name}{tag}.hlo.txt.gz")
            with gzip.open(p, "wt") as f:
                f.write(hlo)
            rec["hlo_path"] = p
    except Exception as e:  # a failing cell is a bug — record loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def lower_stencil_cell(multi_pod: bool, grid_n: int = 1024,
                       overlap: bool = True, time_steps: int = 1):
    """The paper-side cell: acoustic-ISO time step(s), domain-decomposed
    over the production mesh (1024³ f32 grid).  Proves the halo-exchange
    distribution lowers + compiles at pod scale; the XLA inner lowering
    stands in for the Pallas templates (same halo traffic — interpret-mode
    Pallas cannot compile for the CPU target).  ``time_steps`` > 1 lowers
    the overlapped-tiling (time-skewed) variant: k steps per exchange."""
    from repro.core import acoustic, distributed as dist, dsl as st
    mesh = make_production_mesh(multi_pod=multi_pod)
    k = acoustic.acoustic_iso_kernel
    grid_axes = ("pod", "data", "model") if multi_pod \
        else ("data", "model", None)
    if time_steps > 1:
        backend = st.distributed(grid_axes=grid_axes, overlap=False,
                                 time_steps=time_steps, swap=("p0", "p1"))
    else:
        backend = st.distributed(grid_axes=grid_axes, overlap=overlap)
    shape = (grid_n,) * 3
    halos = {g: k.info.halo for g in k.ir.grid_params}
    with sharding.use_activation_mesh(mesh):
        fn = dist.lower_distributed(k.ir, halos, shape, None, backend, mesh)
        interiors = {g: jax.ShapeDtypeStruct(shape, jnp.float32)
                     for g in k.ir.grid_params}
        scal = {"dt": jax.ShapeDtypeStruct((), jnp.float32)}
        lowered = fn.jitted.lower(interiors, scal)
    return lowered, {"step": "stencil_step", "overlap": overlap,
                     "time_steps": time_steps, "grid": shape}, mesh


def run_stencil_cell(multi_pod: bool, grid_n: int = 1024,
                     overlap: bool = True, tag: str = "",
                     save_hlo: bool = False, time_steps: int = 1) -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": f"acoustic-iso-{grid_n}", "shape": "one_step",
           "mesh": mesh_name, "seq_len": grid_n, "global_batch": 1,
           "kind": "stencil", "tag": tag}
    try:
        t0 = time.perf_counter()
        lowered, meta, mesh = lower_stencil_cell(multi_pod, grid_n, overlap,
                                                 time_steps)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        hlo = compiled.as_text()
        stats = hlo_analysis.analyze(hlo, mesh.size)
        from repro.core import acoustic
        k = acoustic.acoustic_iso_kernel
        rec.update(meta)
        rec.update({
            "status": "ok", "n_devices": mesh.size,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": _mem_stats(compiled), "cost": _cost_stats(compiled),
            "hlo_walk": hlo_analysis.summarize(stats),
            "stencil_flops_per_point": k.info.flops_per_point,
            "hlo_len": len(hlo),
        })
        if save_hlo:
            os.makedirs(ARTIFACT_DIR, exist_ok=True)
            p = os.path.join(ARTIFACT_DIR,
                             f"{rec['arch']}__one_step__{mesh_name}{tag}"
                             f".hlo.txt.gz")
            with gzip.open(p, "wt") as f:
                f.write(hlo)
            rec["hlo_path"] = p
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or 'acoustic-iso' (stencil cell)")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="output JSON path")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = list(configs.ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    if args.arch == "acoustic-iso":
        for multi in meshes:
            for overlap in (True, False):
                rec = run_stencil_cell(multi, overlap=overlap,
                                       tag="" if overlap else "-no-overlap",
                                       save_hlo=args.save_hlo)
                records.append(rec)
                hw = rec.get("hlo_walk") or {}
                print(f"[{rec['status']:7s}] {rec['arch']:18s} "
                      f"overlap={overlap} {rec['mesh']:6s} "
                      f"compile={rec.get('compile_s', '-'):>8} "
                      f"mem/dev={_fmt_bytes((rec.get('memory') or {}).get('per_device_total_bytes')):>9} "
                      f"flops/dev={_fmt(hw.get('total_flops')):>10} "
                      f"coll/dev={_fmt_bytes(hw.get('total_collective_bytes')):>9} "
                      f"{rec.get('error', '')}", flush=True)
        archs = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, save_hlo=args.save_hlo)
                records.append(rec)
                mem = (rec.get("memory") or {}).get("per_device_total_bytes")
                flops = (rec.get("hlo_walk") or {}).get("total_flops")
                col = (rec.get("hlo_walk") or {}).get(
                    "total_collective_bytes")
                print(f"[{rec['status']:7s}] {arch:18s} {shape:12s} "
                      f"{rec['mesh']:6s} "
                      f"lower={rec.get('lower_s', '-'):>7} "
                      f"compile={rec.get('compile_s', '-'):>8} "
                      f"mem/dev={_fmt_bytes(mem):>9} "
                      f"flops/dev={_fmt(flops):>10} "
                      f"coll/dev={_fmt_bytes(col):>9} "
                      f"{rec.get('reason', '') or rec.get('error', '')}",
                      flush=True)

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    n_err = sum(r["status"] == "error" for r in records)
    print(f"{len(records)} cells: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, "
          f"{n_err} errors")
    return 1 if n_err else 0


def _fmt(x):
    if x is None:
        return "-"
    for unit in ("", "K", "M", "G", "T", "P", "E"):
        if abs(x) < 1000:
            return f"{x:.1f}{unit}"
        x /= 1000
    return f"{x:.1f}Z"


def _fmt_bytes(x):
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


if __name__ == "__main__":
    raise SystemExit(main())
