"""Serving CLI driver: batched greedy generation on a smoke-sized model.

    python -m repro.launch.serve --arch mixtral-8x7b --requests 16 \
        --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serving.serve_loop import BatchServer, GenConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.tiny(configs.get(args.arch))
    if cfg.family == "audio":
        raise SystemExit("use examples/serve_lm.py for enc-dec serving")
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    server = BatchServer(cfg, params, batch_size=args.batch_size,
                         gen=GenConfig(max_new_tokens=args.max_new,
                                       temperature=args.temperature,
                                       seed=args.seed))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        server.submit(rng.integers(0, cfg.vocab, plen), args.max_new)

    t0 = time.perf_counter()
    done = server.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.result) for r in done.values())
    lat = np.array([r.done_at - r.submitted_at for r in done.values()])
    print(f"served {len(done)} requests, {n_tok} new tokens "
          f"in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    print(f"request latency: p50 {np.percentile(lat, 50):.3f}s  "
          f"p99 {np.percentile(lat, 99):.3f}s  "
          f"max {lat.max():.3f}s")
    for uid, r in sorted(done.items())[:4]:
        print(f"  req {uid}: {r.result[:8]}...")
    return done


if __name__ == "__main__":
    main()
