"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization; smoke tests and benches keep the default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (data=16, model=16) = 256 chips; multi-pod adds a
    leading pod axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh for single-device smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_scaling_mesh(n_devices: int, axis: str = "data"):
    """1-D mesh over the first ``n_devices`` devices — the weak/strong
    scaling ladder of ``benchmarks/distributed_stencil.py`` (1/2/4/8
    forced host devices share one process, so each rung is a sub-mesh).
    """
    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(
            f"mesh wants {n_devices} devices, only {len(devs)} available "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    from jax.sharding import Mesh
    import numpy as np
    return Mesh(np.array(devs[:n_devices]), (axis,))


def mesh_axes(mesh) -> dict:
    """Plain {axis name: size} view of a mesh — the device-free geometry
    descriptor ``core.halo.HaloSpec`` and the autotune/cost-model keys
    consume (also accepts a mapping, passed through)."""
    return dict(mesh.shape) if hasattr(mesh, "shape") else dict(mesh)
