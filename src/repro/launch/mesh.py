"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization; smoke tests and benches keep the default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (data=16, model=16) = 256 chips; multi-pod adds a
    leading pod axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh for single-device smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
