"""Post-SPMD HLO analysis: collective-traffic + FLOP + HBM-byte accounting.

``compiled.cost_analysis()`` counts a ``while`` body's cost ONCE, but jax
lowers ``lax.scan`` to ``while`` — so a 16-microbatch scan over a 32-layer
scan under-reports compute by ~500×, and it reports no collective traffic at
all.  This module parses the optimized HLO text (``compiled.as_text()``),
builds the computation graph (calls / fusions / whiles), extracts each
while's static trip count from its condition computation (jax emits
``compare(iter, constant(N))``), and multiplies nested costs through.

Per-device byte-movement model per collective (ring algorithms), derived
from RESULT sizes (operands are printed name-only in optimized HLO; for
every collective the operand size is a fixed multiple of the result size):

    all-gather          → result · (g-1)/g        (receives all but own)
    all-reduce          → 2 · result · (g-1)/g    (RS + AG phases)
    reduce-scatter      → result · (g-1)          (operand = result·g)
    all-to-all          → result · (g-1)/g
    collective-permute  → result                  (sends one full buffer)

FLOPs:
    dot   — 2 · |result| · contracted extent (lhs shape via symbol table)
    vec   — |result| per elementwise arithmetic op (fusion bodies included)
    transcendental — weighted ×4

HBM bytes: Σ (result + operand) bytes over *materializing* ops with fusion
bodies skipped (a fusion = one read of inputs + one write of outputs — the
HBM-traffic model); bookkeeping ops excluded.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_LINE_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\((.*)$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "negate", "abs", "compare", "select", "clamp", "floor",
    "ceil", "round-nearest-afz", "sign", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "remainder",
}
_ELEMENTWISE_4 = {
    "exponential", "log", "tanh", "sqrt", "rsqrt", "power", "logistic",
    "sine", "cosine", "expm1", "log1p", "cbrt", "erf", "atan2",
}
_BOOKKEEPING = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
    "add-dependency", "opt-barrier", "domain", "iota",
}


def _shapes_of(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = _DTYPE_BYTES[dt]
        for d in dims:
            n *= d
        total += n
    return total


def _elems_of(shapes) -> int:
    total = 0
    for _dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Inst:
    name: str
    op: str
    result_shapes: List            # [(dtype, dims), ...]
    operands: List[str]            # operand instruction names
    attrs: str                     # raw text after the operand parens
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    insts: List[_Inst]
    table: Dict[str, _Inst]


def _split_computations(hlo: str) -> Dict[str, _Comp]:
    """Robust splitter: a header is any line ending in '{' that contains
    ') -> ' (handles tuple-typed params with nested parens)."""
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ") -> " in line:
            tok = line.split()[0]
            if tok == "ENTRY":
                tok = line.split()[1]
            name = tok.lstrip("%")
            cur = _Comp(name, [], {})
            comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, result_txt, op, rest = m.groups()
        # operands: up to the matching close paren — names only in
        # optimized HLO, so scanning up to the first '),' or final ')'
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        opnd_txt, attrs = rest[:i - 1], rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", opnd_txt)
        inst = _Inst(name, op, _shapes_of(result_txt), operands, attrs, line)
        cur.insts.append(inst)
        cur.table[name] = inst
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
        if m:
            return m.group(1)
    return None


def _trip_count(cond: _Comp) -> int:
    """jax scans: condition is ``lt(iter, constant(N))`` — take the max
    integer constant in the condition computation (fallback 1)."""
    best = 1
    for inst in cond.insts:
        for m in re.finditer(r"constant\((\d+)\)", inst.line):
            best = max(best, int(m.group(1)))
    return best


def _group_size(attrs: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return n_devices


# ---------------------------------------------------------------------------
# walk results
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Stats:
    dot_flops: float = 0.0
    vec_flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add_coll(self, op: str, moved: float, k: float = 1.0):
        self.coll_counts[op] = self.coll_counts.get(op, 0.0) + k
        self.coll_bytes[op] = self.coll_bytes.get(op, 0.0) + moved * k

    def merge_scaled(self, o: "Stats", k: float):
        self.dot_flops += o.dot_flops * k
        self.vec_flops += o.vec_flops * k
        self.transcendentals += o.transcendentals * k
        self.hbm_bytes += o.hbm_bytes * k
        for key, v in o.coll_counts.items():
            self.coll_counts[key] = self.coll_counts.get(key, 0.0) + v * k
        for key, v in o.coll_bytes.items():
            self.coll_bytes[key] = self.coll_bytes.get(key, 0.0) + v * k

    @property
    def total_flops(self) -> float:
        return self.dot_flops + self.vec_flops + 4 * self.transcendentals

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _coll_moved(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0 if op != "collective-permute" else float(result_bytes)
    ring = (g - 1) / g
    if op == "all-gather":
        return result_bytes * ring
    if op == "all-reduce":
        return 2 * result_bytes * ring
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * ring
    return float(result_bytes)      # collective-permute


def analyze(hlo_text: str, n_devices: int) -> Stats:
    """Trip-count-aware per-device stats for one executed step."""
    comps = _split_computations(hlo_text)
    fusion_bodies = set()
    for comp in comps.values():
        for inst in comp.insts:
            if inst.op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", inst.attrs)
                if m:
                    fusion_bodies.add(m.group(1))

    memo: Dict[Tuple[str, bool], Stats] = {}

    def _operand_size(comp: _Comp, name: str) -> int:
        src = comp.table.get(name)
        return _bytes_of(src.result_shapes) if src is not None else 0

    def _sliced_access_bytes(fused: _Comp) -> Dict[int, int]:
        """parameter index → charged bytes, for fusion params consumed
        ONLY via dynamic-slice (scan xs buffers: traffic = the slice) or
        only as a dynamic-update-slice target (scan ys buffers: in-place,
        traffic = the update, charged at the root)."""
        users: Dict[str, List[_Inst]] = {}
        param_idx: Dict[str, int] = {}
        for inst in fused.insts:
            if inst.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", inst.line)
                if m:
                    param_idx[inst.name] = int(m.group(1))
            for o in inst.operands:
                users.setdefault(o, []).append(inst)
        out = {}
        for pname, idx in param_idx.items():
            uses = users.get(pname, [])
            if not uses:
                out[idx] = 0
                continue
            charged = 0
            ok = True
            for u in uses:
                if u.op in ("dynamic-slice", "slice"):
                    charged += _bytes_of(u.result_shapes)
                elif u.op == "dynamic-update-slice" and \
                        u.operands and u.operands[0] == pname:
                    charged += 0       # in-place target; update charged at root
                else:
                    ok = False
                    break
            if ok:
                out[idx] = charged
        return out

    def _charged_bytes(comp: _Comp, inst: _Inst) -> float:
        """HBM-traffic model per materializing op.

        * dynamic-update-slice — in-place on real hardware: traffic =
          2 × update bytes (read update, write slice), not the buffer.
        * dynamic-slice / gather — traffic = 2 × result (read the slice /
          gathered rows, write result); the source buffer is untouched.
        * fusion — result + operands, but operands consumed only via
          dynamic-slice inside the fused body (scan xs buffers) charge
          their slice sizes; a DUS root charges update bytes.
        * everything else — result + operands.
        """
        rb = _bytes_of(inst.result_shapes)
        if inst.op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * rb
        if inst.op == "dynamic-update-slice":
            upd = _operand_size(comp, inst.operands[1]) \
                if len(inst.operands) > 1 else rb
            return 2.0 * upd
        if inst.op == "scatter":
            upd = _operand_size(comp, inst.operands[-1]) \
                if inst.operands else rb
            return 2.0 * upd
        if inst.op == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", inst.attrs)
            fused = comps.get(m.group(1)) if m else None
            sliced = _sliced_access_bytes(fused) if fused is not None else {}
            total = float(rb)
            inplace_param: Optional[int] = None
            if fused is not None:
                # DUS root (possibly wrapped in convert/bitcast — a CPU
                # dtype detour that fuses away on TPU): write = the update
                # slice; the updated buffer param is in-place (0 traffic)
                roots = [i for i in fused.insts
                         if i.line.startswith("ROOT")]
                root = roots[0] if roots else None
                while root is not None and root.op in ("convert", "bitcast",
                                                       "copy", "transpose"):
                    root = fused.table.get(root.operands[0]) \
                        if root.operands else None
                if root is not None and root.op == "dynamic-update-slice":
                    total = float(_operand_size(fused, root.operands[1])
                                  if len(root.operands) > 1 else rb)
                    # buffer side: peel converts back to a parameter
                    buf = fused.table.get(root.operands[0]) \
                        if root.operands else None
                    while buf is not None and buf.op in ("convert",
                                                         "bitcast", "copy"):
                        buf = fused.table.get(buf.operands[0]) \
                            if buf.operands else None
                    if buf is not None and buf.op == "parameter":
                        mm = re.search(r"parameter\((\d+)\)", buf.line)
                        if mm:
                            inplace_param = int(mm.group(1))
            for i, o in enumerate(inst.operands):
                if i == inplace_param:
                    continue
                total += sliced.get(i, _operand_size(comp, o))
            return total
        total = float(rb)
        for o in inst.operands:
            total += _operand_size(comp, o)
        return total

    def visit(name: str, in_fusion: bool) -> Stats:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = Stats()            # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        st = Stats()
        for inst in comp.insts:
            op = inst.op
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                rb = _bytes_of(inst.result_shapes)
                g = _group_size(inst.attrs, n_devices)
                st.add_coll(base, _coll_moved(base, rb, g))
            elif op == "dot":
                elems = _elems_of(inst.result_shapes)
                contracted = 1
                m = _CONTRACT_RE.search(inst.attrs)
                if m and inst.operands:
                    lhs = comp.table.get(inst.operands[0])
                    if lhs is not None and lhs.result_shapes:
                        dims = lhs.result_shapes[0][1]
                        for d in (int(x) for x in m.group(1).split(",") if x):
                            if d < len(dims):
                                contracted *= dims[d]
                st.dot_flops += 2.0 * elems * contracted
            elif op in _ELEMENTWISE_1:
                st.vec_flops += _elems_of(inst.result_shapes)
            elif op in _ELEMENTWISE_4:
                st.transcendentals += _elems_of(inst.result_shapes)
            elif op in ("reduce", "reduce-window"):
                st.vec_flops += _elems_of(inst.result_shapes)

            if not in_fusion and op not in _BOOKKEEPING:
                st.hbm_bytes += _charged_bytes(comp, inst)

            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                trips = _trip_count(comps[mc.group(1)]) \
                    if mc and mc.group(1) in comps else 1
                if mb:
                    st.merge_scaled(visit(mb.group(1), in_fusion), trips)
            else:
                for c in _CALLED_RE.findall(inst.attrs):
                    st.merge_scaled(
                        visit(c, in_fusion or c in fusion_bodies), 1.0)
        memo[key] = st
        return st

    entry = _entry_name(hlo_text)
    if entry is None or entry not in comps:
        total = Stats()
        for name in comps:
            if name not in fusion_bodies:
                total.merge_scaled(visit(name, False), 1.0)
        return total
    return visit(entry, False)


def summarize(st: Stats) -> Dict:
    return {
        "dot_flops": float(st.dot_flops),
        "vec_flops": float(st.vec_flops),
        "transcendentals": float(st.transcendentals),
        "total_flops": float(st.total_flops),
        "hbm_bytes": float(st.hbm_bytes),
        "collective_counts": {k: round(v, 1)
                              for k, v in st.coll_counts.items()},
        "collective_bytes": {k: float(v) for k, v in st.coll_bytes.items()},
        "total_collective_bytes": float(st.collective_bytes),
    }


def hbm_bytes(hlo_text: str, n_devices: int = 1) -> float:
    """Trip-count-aware HBM bytes of one executed step — the scalar the
    stencil cost model (core/cost_model.py) charges xla candidates."""
    return analyze(hlo_text, n_devices).hbm_bytes


# -- back-compat wrappers (dryrun.py uses these names) -----------------------
def collective_stats(hlo_text: str, n_devices: int) -> Stats:
    return analyze(hlo_text, n_devices)


def op_stats(hlo_text: str, n_devices: int = 1) -> Stats:
    return analyze(hlo_text, n_devices)
