"""Training CLI driver.

Real-hardware entry point (and the smoke path used by examples/tests)::

    python -m repro.launch.train --arch mixtral-8x7b --steps 100 \
        --ckpt-dir /tmp/ckpt --preset smoke

``--preset smoke`` shrinks the arch to its reduced same-family config and
runs on the host devices; ``--preset full`` uses the real config and the
production mesh (requires a pod).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, sharding
from repro.configs.shapes import SHAPES
from repro.models import api
from repro.train import checkpoint, data, fault_tolerance, optimizer, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "smoke":
        cfg = configs.tiny(cfg)
        seq = args.seq_len or 128
        gb = args.global_batch or 8
        mesh = None
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        seq = args.seq_len or SHAPES["train_4k"].seq_len
        gb = args.global_batch or SHAPES["train_4k"].global_batch

    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=seq,
                                global_batch=gb)
    batch_fn = data.make_batch_fn(cfg, shape, seed=args.seed)

    oc = optimizer.OptConfig(lr=args.lr, warmup_steps=args.warmup,
                             total_steps=max(args.steps, 1))
    tc = train_loop.TrainConfig(opt=oc, n_microbatches=args.microbatches)
    step_fn = train_loop.make_train_step(cfg, tc)
    if mesh is not None:
        st_shard = train_loop.state_shardings(cfg, mesh)
        jitted = jax.jit(step_fn, in_shardings=(st_shard, None),
                         out_shardings=(st_shard, None),
                         donate_argnums=(0,))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))

    def init_fn():
        return train_loop.init_state(cfg, jax.random.PRNGKey(args.seed))

    losses = []

    def one_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        state, metrics = jitted(state, batch)
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return state

    t0 = time.perf_counter()
    if args.ckpt_dir:
        wd = fault_tolerance.Watchdog()
        state = fault_tolerance.run_with_restarts(
            init_fn=init_fn, step_fn=one_step, n_steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, watchdog=wd)
        if wd.events:
            print(f"straggler events: {len(wd.events)}")
    else:
        state = init_fn()
        for step in range(args.steps):
            state = one_step(state, step)
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt / max(args.steps, 1):.2f} s/step)")
    return losses


if __name__ == "__main__":
    main()
