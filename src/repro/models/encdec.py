"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings [B, T_enc, D] (kernels/conv1d demonstrates the
real strided-conv op as a generated 1-D stencil).  Encoder = bidirectional
attention; decoder = causal self-attention + cross-attention; GELU MLPs,
LayerNorm, learned positions replaced by RoPE (backbone shape params only
are mandated; noted in DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from . import layers as L


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": L.init_norm(cfg.d_model, cfg),
            "attn": L.init_attention(ks[0], cfg),
            "ln2": L.init_norm(cfg.d_model, cfg),
            "mlp": L.init_mlp(ks[1], cfg)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": L.init_norm(cfg.d_model, cfg),
            "attn": L.init_attention(ks[0], cfg),
            "lnx": L.init_norm(cfg.d_model, cfg),
            "xattn": L.init_attention(ks[1], cfg),
            "ln2": L.init_norm(cfg.d_model, cfg),
            "mlp": L.init_mlp(ks[2], cfg)}


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    ek = jax.random.split(ks[0], cfg.n_enc_layers)
    dk = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "embed": L.init_embedding(ks[2], cfg),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ek),
        "enc_norm": L.init_norm(cfg.d_model, cfg),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dk),
        "final_norm": L.init_norm(cfg.d_model, cfg),
    }


def encode(params, frame_embeds, cfg: ModelConfig):
    """frame_embeds: [B, T, D] (stub frontend output)."""
    x = frame_embeds.astype(L.cdtype(cfg))
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(lp, x):
        h, _ = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), cfg,
                           mode="bidir", positions=pos)
        x = x + h
        return x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, cfg), cfg)

    body = L.remat_wrap(cfg)(body)

    def scan_body(x, lp):
        return body(lp, x), None

    x, _ = lax.scan(scan_body, x, params["enc_layers"])
    return L.norm(params["enc_norm"], x, cfg)


def _cross_kv(lp, enc_out, cfg):
    dt = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, lp["xattn"]["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, lp["xattn"]["wv"].astype(dt))
    return k, v


def decode_train(params, enc_out, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    T = enc_out.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(lp, x):
        h, _ = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), cfg,
                           mode="causal", positions=pos)
        x = x + h
        kv = _cross_kv(lp, enc_out, cfg)
        h, _ = L.attention(lp["xattn"], L.norm(lp["lnx"], x, cfg), cfg,
                           positions=pos, kv=kv, kv_positions=kv_pos)
        x = x + h
        return x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, cfg), cfg)

    body = L.remat_wrap(cfg)(body)

    def scan_body(x, lp):
        return body(lp, x), None

    x, _ = lax.scan(scan_body, x, params["dec_layers"])
    return L.norm(params["final_norm"], x, cfg)


def forward(params, batch: Dict, cfg: ModelConfig):
    """batch: {'frame_embeds': [B,T,D], 'tokens': [B,S]} → (hidden, aux)."""
    enc = encode(params, batch["frame_embeds"], cfg)
    hid = decode_train(params, enc, batch["tokens"], cfg)
    return hid, jnp.float32(0.0)


# -- decode with cache --------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 1500):
    dt = L.cdtype(cfg)
    hd = cfg.resolved_head_dim
    Ld = cfg.n_dec_layers
    return {
        "kv": {"k": jnp.zeros((Ld, batch, cache_len, cfg.n_kv_heads, hd), dt),
               "v": jnp.zeros((Ld, batch, cache_len, cfg.n_kv_heads, hd), dt)},
        "xk": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, hd), dt),
        "xv": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 1500):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, cache_len, enc_len))


def build_cache(params, enc_out, cfg: ModelConfig, batch: int, cache_len: int):
    """Precompute per-layer cross K/V from encoder output."""
    cache = init_cache(cfg, batch, cache_len, enc_out.shape[1])

    def per_layer(lp):
        return _cross_kv(lp, enc_out, cfg)

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    dt = L.cdtype(cfg)
    return dict(cache, xk=xk.astype(dt), xv=xv.astype(dt))


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32), (B, S))
    T = cache["xk"].shape[2]
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def scan_body(x, lpkv):
        lp, k, v, xk, xv = lpkv
        lcache = {"k": k, "v": v, "pos": pos}
        h, nc = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), cfg,
                            mode="causal", positions=positions, cache=lcache)
        x = x + h
        h, _ = L.attention(lp["xattn"], L.norm(lp["lnx"], x, cfg), cfg,
                           positions=positions, kv=(xk, xv),
                           kv_positions=kv_pos)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, cfg), cfg)
        return x, (nc["k"], nc["v"])

    x, (k2, v2) = lax.scan(scan_body, x,
                           (params["dec_layers"], cache["kv"]["k"],
                            cache["kv"]["v"], cache["xk"], cache["xv"]))
    x = L.norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, dict(cache, kv={"k": k2, "v": v2}, pos=pos + S)
