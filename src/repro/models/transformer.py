"""Decoder-only transformer LM (dense / MoE / SWA / VLM-prefix variants).

Scan-over-layers with stacked params (compile-size hygiene for 32–56 layer
configs), optional per-layer remat, GQA attention with sliding window,
MoE FFN, and a decode path over a (rolling-buffer) KV cache.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from . import layers as L
from .moe import init_moe, moe_ffn


def init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"ln1": L.init_norm(cfg.d_model, cfg),
         "attn": L.init_attention(ks[0], cfg),
         "ln2": L.init_norm(cfg.d_model, cfg)}
    if cfg.moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    return p


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {"embed": L.init_embedding(ks[1], cfg),
            "layers": stacked,
            "final_norm": L.init_norm(cfg.d_model, cfg)}


def _layer_fwd(lp, x, cfg: ModelConfig, positions):
    h, _ = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), cfg,
                       mode="causal", window=cfg.window, positions=positions)
    x = x + h
    hin = L.norm(lp["ln2"], x, cfg)
    if cfg.moe:
        h, aux = moe_ffn(lp["moe"], hin, cfg)
    else:
        h, aux = L.mlp(lp["mlp"], hin, cfg), jnp.float32(0.0)
    return x + h, aux


def forward(params, tokens, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None):
    """tokens: [B, S] int32; prefix_embeds: [B, P, D] (VLM patch stub).
    Returns (hidden [B, S_total, D], aux_loss)."""
    x = L.embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    body = partial(_layer_fwd, cfg=cfg, positions=positions)
    body = L.remat_wrap(cfg)(body)

    if cfg.scan_layers:
        def scan_body(carry, lp):
            x, aux = carry
            x, a = body(lp, x)
            return (x, aux + a), None
        (x, aux), _ = lax.scan(scan_body, (x, jnp.float32(0.0)),
                               params["layers"])
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body(lp, x)
            aux = aux + a

    x = L.norm(params["final_norm"], x, cfg)
    return x, aux


def logits_from_hidden(params, hidden, cfg: ModelConfig):
    return L.unembed(params["embed"], hidden, cfg)


# --------------------------------------------------------------------------
# decode path (one new token against a KV cache)
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Rolling-buffer KV cache.  For SWA archs cache_len=window (bounded);
    for full attention cache_len=context."""
    dt = dtype or L.cdtype(cfg)
    hd = cfg.resolved_head_dim
    kv = {"k": jnp.zeros((cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dt),
          "v": jnp.zeros((cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dt)}
    return {"kv": kv, "pos": jnp.zeros((), jnp.int32)}


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    hd = cfg.resolved_head_dim
    dt = L.cdtype(cfg)
    return {"kv": {"k": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dt),
                   "v": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd), dt)},
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """tokens: [B, 1] — decode one token.  Returns (logits [B,1,V], cache')."""
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32), (B, S))

    def scan_body(x, lpkv):
        lp, k, v = lpkv
        lcache = {"k": k, "v": v, "pos": pos}
        h, nc = L.attention(lp["attn"], L.norm(lp["ln1"], x, cfg), cfg,
                            mode="causal", window=cfg.window,
                            positions=positions, cache=lcache)
        x = x + h
        hin = L.norm(lp["ln2"], x, cfg)
        if cfg.moe:
            h, _ = moe_ffn(lp["moe"], hin, cfg, dropless=True)
        else:
            h = L.mlp(lp["mlp"], hin, cfg)
        return x + h, (nc["k"], nc["v"])

    x, (k2, v2) = lax.scan(scan_body, x,
                           (params["layers"], cache["kv"]["k"],
                            cache["kv"]["v"]))
    x = L.norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)
    return logits, {"kv": {"k": k2, "v": v2}, "pos": pos + S}


def prefill(params, tokens, cfg: ModelConfig, cache_len: int):
    """Run the full prompt and build a decode cache (example/serving path)."""
    x = L.embed(params["embed"], tokens, cfg)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    dt = L.cdtype(cfg)
    hd = cfg.resolved_head_dim

    def scan_body(x, lp):
        xn = L.norm(lp["ln1"], x, cfg)
        k = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", xn, lp["attn"]["wv"].astype(dt))
        k = L.rope(k, positions, cfg.rope_theta)
        h, _ = L.attention(lp["attn"], xn, cfg, mode="causal",
                           window=cfg.window, positions=positions)
        x = x + h
        hin = L.norm(lp["ln2"], x, cfg)
        if cfg.moe:
            h, _ = moe_ffn(lp["moe"], hin, cfg)
        else:
            h = L.mlp(lp["mlp"], hin, cfg)
        return x + h, (k, v)

    x, (ks, vs) = lax.scan(scan_body, x, params["layers"])
    x = L.norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)

    # place the last cache_len positions into the rolling buffer at the
    # slots they belong to (slot = pos % cache_len)
    cache = init_cache(cfg, B, cache_len)
    take = min(S, cache_len)
    src_k = ks[:, :, S - take:]
    src_v = vs[:, :, S - take:]
    pos = jnp.arange(S - take, S, dtype=jnp.int32)
    slots = pos % cache_len
    k0 = cache["kv"]["k"].at[:, :, slots].set(src_k.astype(dt))
    v0 = cache["kv"]["v"].at[:, :, slots].set(src_v.astype(dt))
    return logits, {"kv": {"k": k0, "v": v0},
                    "pos": jnp.int32(S)}
