"""Family-dispatching model API: init / forward / loss / decode / caches.

This is the single entry point the training stack, serving stack, dry-run
and tests use; ``cfg.family`` picks the backbone module.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from . import encdec, griffin, layers as L, transformer, xlstm

_FAMILY = {"dense": transformer, "moe": transformer, "vlm": transformer,
           "hybrid": griffin, "ssm": xlstm, "audio": encdec}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return module_for(cfg).init_params(key, cfg)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for l in jax.tree.leaves(param_shapes(cfg)):
        n = 1
        for s in l.shape:
            n *= int(s)
        total += n
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: experts count at top_k/E; everything else fully active."""
    total = 0
    shapes = param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= int(s)
        keys = "/".join(str(p) for p in path)
        if cfg.moe and "moe" in keys and "router" not in keys:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total


def forward_hidden(cfg: ModelConfig, params, batch: Dict):
    """→ (hidden_for_logits [B, S_tok, D], aux_loss)."""
    mod = module_for(cfg)
    if cfg.family == "audio":
        return mod.forward(params, batch, cfg)
    prefix = batch.get("patch_embeds")
    hid, aux = mod.forward(params, batch["tokens"], cfg, prefix_embeds=prefix)
    if prefix is not None:
        hid = hid[:, prefix.shape[1]:]
    return hid, aux


def _ce_from_logits(logits, labels):
    """Mean token cross-entropy, f32 logsumexp."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return (lse - picked).mean()


def loss_fn(cfg: ModelConfig, params, batch: Dict):
    """→ (loss, metrics).  Vocab-heavy configs use sequence-chunked CE so
    the [B,S,V] logits never materialize (cfg.logits_chunk)."""
    hid, aux = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    embed_p = params["embed"]

    if cfg.logits_chunk:
        C = cfg.logits_chunk
        B, S, D = hid.shape
        pad = (-S) % C
        if pad:
            hid = jnp.pad(hid, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=0)
        n = hid.shape[1] // C
        hc = constrain(hid.reshape(B, n, C, D).swapaxes(0, 1),
                       None, "batch", None, "embed")
        yc = labels.reshape(B, n, C).swapaxes(0, 1)
        valid = (jnp.arange(hid.shape[1]) < S).reshape(n, C)

        @jax.checkpoint
        def chunk_loss(h, y, v):
            logits = L.unembed(embed_p, h, cfg).astype(jnp.float32)
            logits = constrain(logits, "batch", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
            return ((lse - picked) * v[None]).sum()

        def scan_body(tot, xs):
            h, y, v = xs
            return tot + chunk_loss(h, y, v), None

        total, _ = lax.scan(scan_body, jnp.float32(0.0), (hc, yc, valid))
        ce = total / (B * S)
    else:
        logits = L.unembed(embed_p, hid, cfg)
        ce = _ce_from_logits(logits, labels)

    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# -- decode ------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, **kw):
    return module_for(cfg).init_cache(cfg, batch, cache_len, **kw)


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, **kw):
    return module_for(cfg).cache_spec(cfg, batch, cache_len, **kw)


def decode_step(cfg: ModelConfig, params, cache, tokens):
    return module_for(cfg).decode_step(params, cache, tokens, cfg)


def decode_cache_len(cfg: ModelConfig, context_len: int) -> int:
    """Rolling-buffer size: SWA archs bound it by the window."""
    if cfg.family == "hybrid":
        return min(cfg.local_window or context_len, context_len)
    if cfg.family == "ssm":
        return 0
    if cfg.window:
        return min(cfg.window, context_len)
    return context_len
