"""Mixture-of-Experts FFN (Mixtral-style top-k routing, GShard capacity
dispatch).

Tokens are grouped (``moe.group_size``) and dispatched to expert buffers via
cumsum-assigned positions + one-hot einsums — shape-static, GSPMD-friendly,
with dispatch FLOPs ≪ expert FLOPs for realistic group sizes.  Tokens beyond
an expert's capacity are dropped (capacity_factor 1.25, as GShard).

Sharding: experts' d_ff is tensor-parallel over 'model'; expert weights are
additionally FSDP-sharded over 'data' on d_model.  (True expert-parallelism
over a dedicated mesh axis needs n_experts | axis size — with E=8 on a
16-wide model axis we TP instead; see DESIGN.md §6 and the §Perf EP
experiment.)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from .layers import dense_init, pdtype, _split


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    dt = pdtype(cfg)
    ks = _split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), dt),
        "wg": dense_init(ks[1], (E, d, f), dt),
        "wu": dense_init(ks[2], (E, d, f), dt),
        "wo": dense_init(ks[3], (E, f, d), dt, scale=f ** -0.5),
    }


def moe_ffn(p, x, cfg: ModelConfig,
            dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] → (out [B, S, D], aux_loss scalar).

    ``dropless=True`` sets capacity C = G·K (no token ever dropped) — used
    by the decode path so single-token routing matches training routing
    exactly regardless of grouping (GShard capacity dropping is otherwise
    grouping-dependent)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    dt = x.dtype

    T = B * S
    G = max(1, min(m.group_size, T))
    n_groups = T // G
    # group size must divide tokens; configs pick group_size | B·S.
    # the group dim carries the batch dim's sharding (n_groups % dp == 0
    # for the assigned shapes)
    xg = constrain(x.reshape(n_groups, G, D), "batch", None, None)

    logits = jnp.einsum("ngd,de->nge", xg, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [n, G, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch/GShard form)
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = jax.nn.one_hot(gate_idx[..., 0], E).mean(axis=(0, 1))
    aux = m.aux_loss_weight * E * jnp.sum(me * ce)

    if dropless:
        C = G * K
    else:
        C = int(m.capacity_factor * G * K / E + 0.5)
    C = max(C, 1)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # [n,G,K,E]
    # position of each (token, k) inside its expert buffer
    pos = jnp.cumsum(onehot.reshape(n_groups, G * K, E), axis=1) - 1.0
    pos = pos.reshape(n_groups, G, K, E)
    within = (pos < C) & (onehot > 0)
    pos = jnp.where(within, pos, 0.0).astype(jnp.int32)

    # dispatch one-hot [n, G, E, C] (summed over the K routing slots)
    disp = (jax.nn.one_hot(pos, C, dtype=dt)
            * within[..., None].astype(dt)).sum(axis=2)
    expert_in = jnp.einsum("ngec,ngd->encd", disp, xg)      # [E, n, C, D]
    expert_in = constrain(expert_in, "experts", "batch", None, None)

    g = jnp.einsum("encd,edf->encf", expert_in, p["wg"].astype(dt))
    u = jnp.einsum("encd,edf->encf", expert_in, p["wu"].astype(dt))
    g = constrain(g, "experts", "batch", None, "ff")
    u = constrain(u, "experts", "batch", None, "ff")
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("encf,efd->encd", h, p["wo"].astype(dt))
    expert_out = constrain(expert_out, "experts", "batch", None, None)

    # combine weights [n, G, E, C]: the gate value where dispatched
    comb = (jax.nn.one_hot(pos, C, dtype=jnp.float32)
            * (gate_vals[..., None] * within.astype(jnp.float32))[..., None])
    comb = comb.sum(axis=2).astype(dt)
    out = jnp.einsum("ngec,encd->ngd", comb, expert_out)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
