"""xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory,
sequential scan) blocks [arXiv:2405.04517].

Training uses the chunkwise form of the mLSTM recurrence (linear-attention
style: inter-chunk state carried by a lax.scan over chunks; intra-chunk
causal matmul) — O(S·chunk) memory, exact w.r.t. the sequential recurrence
up to the log-domain stabilizer.  Decode keeps O(1) state per layer
(C [B,H,dk,dv], n [B,H,dk], m [B,H]) so the 500k-context shape runs.

sLSTM blocks (every ``slstm_every``-th layer) use a sequential lax.scan —
their exponential-gate normalizer is a true serial dependency.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain
from . import layers as L


def block_types(cfg: ModelConfig):
    n = cfg.slstm_every or 0
    return ["slstm" if (n and (i + 1) % n == 0) else "mlstm"
            for i in range(cfg.n_layers)]


def pattern_of(cfg: ModelConfig):
    """Repeating block cycle: (mlstm ×(k−1), slstm) for slstm_every=k, or
    a single mlstm.  Cycles are stacked + scanned (compile-size hygiene)."""
    P = cfg.slstm_every or 1
    return tuple(block_types(cfg)[:P])


def _cycle_split(cfg: ModelConfig):
    P = len(pattern_of(cfg))
    return cfg.n_layers // P, cfg.n_layers % P


def _heads(cfg):
    return cfg.n_heads, cfg.resolved_head_dim


def init_mlstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    H, hd = _heads(cfg)
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 7)
    return {
        "ln": L.init_norm(d, cfg),
        "wq": L.dense_init(ks[0], (d, H, hd), dt),
        "wk": L.dense_init(ks[1], (d, H, hd), dt),
        "wv": L.dense_init(ks[2], (d, H, hd), dt),
        "wi": L.dense_init(ks[3], (d, H), dt),      # input gate (exp)
        "wf": L.dense_init(ks[4], (d, H), dt),      # forget gate
        "bf": jnp.full((H,), 3.0, dt),              # long-memory init
        "wo_gate": L.dense_init(ks[5], (d, H, hd), dt),
        "wo": L.dense_init(ks[6], (H, hd, d), dt, scale=(H * hd) ** -0.5),
    }


def init_slstm_block(key, cfg: ModelConfig):
    d = cfg.d_model
    H, hd = _heads(cfg)
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 5)
    return {
        "ln": L.init_norm(d, cfg),
        "wz": L.dense_init(ks[0], (d, H, hd), dt),
        "wi": L.dense_init(ks[1], (d, H, hd), dt),
        "wf": L.dense_init(ks[2], (d, H, hd), dt),
        "wo_gate": L.dense_init(ks[3], (d, H, hd), dt),
        "bf": jnp.full((H, hd), 3.0, dt),
        "wo": L.dense_init(ks[4], (H, hd, d), dt, scale=(H * hd) ** -0.5),
    }


def _init_block(key, cfg, t):
    return init_mlstm_block(key, cfg) if t == "mlstm" \
        else init_slstm_block(key, cfg)


def init_params(key, cfg: ModelConfig):
    pat = pattern_of(cfg)
    n_cycles, tail = _cycle_split(cfg)
    ks = jax.random.split(key, 3)

    def init_cycle(k):
        kk = jax.random.split(k, len(pat))
        return {str(p): _init_block(kk[p], cfg, t)
                for p, t in enumerate(pat)}

    cycles = jax.vmap(init_cycle)(jax.random.split(ks[0], n_cycles)) \
        if n_cycles else {}
    tail_keys = jax.random.split(ks[1], max(tail, 1))
    tail_blocks = [_init_block(tail_keys[p], cfg, pat[p])
                   for p in range(tail)]
    return {"embed": L.init_embedding(ks[2], cfg),
            "cycles": cycles,
            "tail": tail_blocks,
            "final_norm": L.init_norm(cfg.d_model, cfg)}


# --------------------------------------------------------------------------
# mLSTM chunkwise
# --------------------------------------------------------------------------
def _mlstm_proj(p, xn):
    dt = xn.dtype
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(dt))
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "heads", "head_dim")
    v = constrain(v, "batch", "seq", "heads", "head_dim")
    i_pre = jnp.einsum("bsd,dh->bsh", xn, p["wi"].astype(dt)).astype(jnp.float32)
    f_pre = (jnp.einsum("bsd,dh->bsh", xn, p["wf"].astype(dt))
             + p["bf"].astype(dt)).astype(jnp.float32)
    og = jax.nn.sigmoid(
        jnp.einsum("bsd,dhk->bshk", xn, p["wo_gate"].astype(dt)))
    return q, k, v, i_pre, f_pre, og


def mlstm_chunkwise(p, x, cfg: ModelConfig):
    """x: [B,S,D] → [B,S,D]; chunk = cfg.chunk (S % chunk == 0 assumed
    after padding)."""
    B, S, D = x.shape
    H, hd = _heads(cfg)
    xn = L.norm(p["ln"], x, cfg)
    C = min(cfg.chunk, S)
    pad = (-S) % C
    if pad:
        xn = jnp.pad(xn, ((0, 0), (0, pad), (0, 0)))
    Sp = xn.shape[1]
    n_ch = Sp // C

    q, k, v, i_pre, f_pre, og = _mlstm_proj(p, xn)
    scale = hd ** -0.5
    logf = jax.nn.log_sigmoid(f_pre)                 # [B,Sp,H]

    def resh(a):
        return a.reshape(B, n_ch, C, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = resh(q), resh(k), resh(v)
    ic, fc = resh(i_pre), resh(logf)

    def chunk_step(carry, xs):
        # Carried state is stabilized: true_C = Cst · exp(mst).
        Cst, nst, mst = carry          # [B,H,hd,hd], [B,H,hd], [B,H]
        qb, kb, vb, ib, fb = xs        # [B,C,...]
        fcum = jnp.cumsum(fb, axis=1)                    # [B,C,H] Σ_{r≤t}logf
        ftot = fcum[:, -1]                               # [B,H]
        g = ib - fcum                                    # i_s − fcum_s
        b = lax.cummax(g, axis=1)                        # running max over s≤t
        Mt = jnp.maximum(mst[:, None], b)                # [B,C,H]
        m_t = fcum + Mt                                  # per-t stabilizer
        # inter-chunk: q_t reads prev state decayed by exp(fcum_t)
        w_state = jnp.exp(mst[:, None] - Mt)             # ≤ 1
        inter = jnp.einsum("bchk,bhkl->bchl", qb * scale,
                           Cst.astype(qb.dtype))
        inter = inter * w_state[..., None].astype(qb.dtype)
        n_inter = jnp.einsum("bchk,bhk->bch",
                             (qb * scale).astype(jnp.float32), nst) * w_state
        # intra-chunk causal: weight(t,s) = exp(g_s − Mt_t) for s ≤ t
        dmat = g[:, None] - Mt[:, :, None]               # [B,t,s,H]
        causal = jnp.tril(jnp.ones((C, C), bool))
        wmat = jnp.where(causal[None, :, :, None], jnp.exp(dmat), 0.0)
        scores = jnp.einsum("bchk,bshk->bcsh", qb * scale, kb)
        sw = scores.astype(jnp.float32) * wmat
        intra = jnp.einsum("bcsh,bshl->bchl", sw.astype(vb.dtype), vb)
        n_intra = sw.sum(axis=2)                         # [B,C,H]
        num = inter + intra
        den = jnp.abs(n_inter + n_intra)
        den = jnp.maximum(den, jnp.exp(-m_t))
        out = num / den[..., None].astype(num.dtype)
        # state update to end of chunk: new stabilizer m' = ftot + Mend
        Mend = jnp.maximum(mst, b[:, -1])                # [B,H]
        w_k = jnp.exp(g - Mend[:, None])                 # ≤ 1  [B,C,H]
        kv = jnp.einsum("bchk,bchl->bhkl",
                        kb.astype(jnp.float32) * w_k[..., None],
                        vb.astype(jnp.float32))
        decay = jnp.exp(mst - Mend)
        C2 = Cst * decay[..., None, None] + kv
        n2 = nst * decay[..., None] + \
            jnp.einsum("bchk,bch->bhk", kb.astype(jnp.float32), w_k)
        return (C2, n2, ftot + Mend), out

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, outs = lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    out = outs.swapaxes(0, 1).reshape(B, Sp, H, hd)[:, :S]
    out = out * og[:, :S].astype(out.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y


def mlstm_step(p, x, state, cfg: ModelConfig):
    """Decode: x [B,1,D]; state (C,n,m)."""
    B = x.shape[0]
    H, hd = _heads(cfg)
    xn = L.norm(p["ln"], x, cfg)
    q, k, v, i_pre, f_pre, og = _mlstm_proj(p, xn)
    q, k, v, og = q[:, 0], k[:, 0], v[:, 0], og[:, 0]
    i_t, logf = i_pre[:, 0], jax.nn.log_sigmoid(f_pre[:, 0])
    Cst, nst, mst = state
    m_new = jnp.maximum(logf + mst, i_t)
    wf = jnp.exp(logf + mst - m_new)
    wi = jnp.exp(i_t - m_new)
    kv = jnp.einsum("bhk,bhl->bhkl", k.astype(jnp.float32) * wi[..., None],
                    v.astype(jnp.float32))
    C2 = Cst * wf[..., None, None] + kv
    n2 = nst * wf[..., None] + k.astype(jnp.float32) * wi[..., None]
    scale = hd ** -0.5
    num = jnp.einsum("bhk,bhkl->bhl", (q * scale).astype(jnp.float32), C2)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", (q * scale).astype(jnp.float32), n2))
    den = jnp.maximum(den, jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype) * og
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(x.dtype))
    return x + y[:, None], (C2, n2, m_new)


# --------------------------------------------------------------------------
# sLSTM (sequential)
# --------------------------------------------------------------------------
def _slstm_proj(p, xn):
    dt = xn.dtype
    z = jnp.einsum("bsd,dhk->bshk", xn, p["wz"].astype(dt))
    i = jnp.einsum("bsd,dhk->bshk", xn, p["wi"].astype(dt)).astype(jnp.float32)
    f = (jnp.einsum("bsd,dhk->bshk", xn, p["wf"].astype(dt))
         + p["bf"].astype(dt)).astype(jnp.float32)
    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", xn,
                                  p["wo_gate"].astype(dt)))
    return z, i, f, o


def _slstm_cell(carry, xs):
    c, n, m = carry
    z_t, i_t, f_t = xs
    logf = jax.nn.log_sigmoid(f_t)
    m2 = jnp.maximum(logf + m, i_t)
    wf = jnp.exp(logf + m - m2)
    wi = jnp.exp(i_t - m2)
    c2 = wf * c + wi * jnp.tanh(z_t)
    n2 = wf * n + wi
    h = c2 / jnp.maximum(n2, 1e-6)
    return (c2, n2, m2), h


def slstm_seq(p, x, cfg: ModelConfig):
    B, S, D = x.shape
    H, hd = _heads(cfg)
    xn = L.norm(p["ln"], x, cfg)
    z, i, f, o = _slstm_proj(p, xn)
    c0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H, hd), -1e30, jnp.float32)
    (cT, nT, mT), hs = lax.scan(
        _slstm_cell, (c0, c0, m0),
        (z.swapaxes(0, 1).astype(jnp.float32),
         i.swapaxes(0, 1), f.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1).astype(x.dtype) * o
    y = jnp.einsum("bshk,hkd->bsd", h, p["wo"].astype(x.dtype))
    return x + y


def slstm_step(p, x, state, cfg: ModelConfig):
    xn = L.norm(p["ln"], x, cfg)
    z, i, f, o = _slstm_proj(p, xn)
    (c2, n2, m2), h = _slstm_cell(state, (z[:, 0].astype(jnp.float32),
                                          i[:, 0], f[:, 0]))
    y = jnp.einsum("bhk,hkd->bd", h.astype(x.dtype) * o[:, 0],
                   p["wo"].astype(x.dtype))
    return x + y[:, None], (c2, n2, m2)


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------
def forward(params, tokens, cfg: ModelConfig,
            prefix_embeds: Optional[jnp.ndarray] = None):
    x = L.embed(params["embed"], tokens, cfg)
    pat = pattern_of(cfg)
    n_cycles, tail = _cycle_split(cfg)

    def cycle_fwd(cyc, x):
        for p, t in enumerate(pat):
            fn = mlstm_chunkwise if t == "mlstm" else slstm_seq
            x = fn(cyc[str(p)], x, cfg=cfg)
        return x

    body = L.remat_wrap(cfg)(cycle_fwd)
    if n_cycles:
        def scan_body(x, cyc):
            return body(cyc, x), None
        x, _ = lax.scan(scan_body, x, params["cycles"])
    for p in range(tail):
        fn = mlstm_chunkwise if pat[p] == "mlstm" else slstm_seq
        x = fn(params["tail"][p], x, cfg=cfg)
    x = L.norm(params["final_norm"], x, cfg)
    return x, jnp.float32(0.0)


def _block_state(cfg: ModelConfig, t: str, batch: int,
                 lead: Tuple[int, ...] = ()):
    H, hd = _heads(cfg)
    if t == "mlstm":
        return (jnp.zeros(lead + (batch, H, hd, hd), jnp.float32),
                jnp.zeros(lead + (batch, H, hd), jnp.float32),
                jnp.full(lead + (batch, H), -1e30, jnp.float32))
    return (jnp.zeros(lead + (batch, H, hd), jnp.float32),
            jnp.zeros(lead + (batch, H, hd), jnp.float32),
            jnp.full(lead + (batch, H, hd), -1e30, jnp.float32))


def init_cache(cfg: ModelConfig, batch: int, cache_len: int = 0):
    pat = pattern_of(cfg)
    n_cycles, tail = _cycle_split(cfg)
    cycles = {str(p): _block_state(cfg, t, batch, (n_cycles,))
              for p, t in enumerate(pat)} if n_cycles else {}
    tails = [_block_state(cfg, pat[p], batch) for p in range(tail)]
    return {"cycles": cycles, "tail": tails,
            "pos": jnp.zeros((), jnp.int32)}


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int = 0):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))


def decode_step(params, cache, tokens, cfg: ModelConfig):
    x = L.embed(params["embed"], tokens, cfg)
    pat = pattern_of(cfg)
    n_cycles, tail = _cycle_split(cfg)

    if n_cycles:
        def scan_body(x, xs):
            cyc, states = xs
            new_states = {}
            for p, t in enumerate(pat):
                step = mlstm_step if t == "mlstm" else slstm_step
                x, ns = step(cyc[str(p)], x, states[str(p)], cfg)
                new_states[str(p)] = ns
            return x, new_states

        x, new_cycles = lax.scan(scan_body, x,
                                 (params["cycles"], cache["cycles"]))
    else:
        new_cycles = {}
    new_tail = []
    for p in range(tail):
        step = mlstm_step if pat[p] == "mlstm" else slstm_step
        x, ns = step(params["tail"][p], x, cache["tail"][p], cfg)
        new_tail.append(ns)
    x = L.norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"cycles": new_cycles, "tail": new_tail,
                    "pos": cache["pos"] + 1}
