"""LM model substrate: family backbones + the dispatching api module."""
