"""Shared LM layers, functional style (params are plain pytrees of arrays).

Conventions
-----------
* ``init_*`` functions return dicts of ``jax.ShapeDtypeStruct``-compatible
  arrays when given a PRNG key, or pure shape trees via ``jax.eval_shape``.
* Activations run in ``cfg.dtype`` (bf16); params are stored in
  ``cfg.param_dtype`` (fp32 master) and cast at use.
* Attention supports GQA/MQA, causal/bidirectional/sliding-window masks,
  optional blockwise-KV online-softmax (``cfg.attn_chunk``) and KV-cache
  decode (full cache or rolling window buffer).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding import constrain, kv_cache_mode


def remat_wrap(cfg: ModelConfig):
    """Layer-body remat transform per cfg: 'full' recomputes everything in
    the backward pass (min memory, max recompute + re-all-gather of FSDP
    weights); 'dots' saves matmul outputs (no matmul recompute ⇒ no second
    FSDP weight gather in bwd, at higher activation memory)."""
    if not cfg.remat:
        return lambda f: f
    if cfg.remat_policy == "dots":
        return partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in ** -0.5
    return (s * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_rmsnorm(d, cfg):
    return {"scale": jnp.ones((d,), pdtype(cfg))}


def rmsnorm(p, x, eps=1e-6):
    """Variance reduction in f32, but the x-path multiply stays in the
    input dtype — otherwise the f32 cast boundary sits between the layer's
    einsums and the TP backward all-reduce and XLA hoists the convert
    before the collective, doubling its bytes (observed: 600 GB/step of
    f32 ARs on mixtral train; §Perf pair-1 iteration 3)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps).astype(dt)
    return x * inv * p["scale"].astype(dt)


def init_layernorm(d, cfg):
    return {"scale": jnp.ones((d,), pdtype(cfg)),
            "bias": jnp.zeros((d,), pdtype(cfg))}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps).astype(dt)
    out = (x - mu.astype(dt)) * inv
    return out * p["scale"].astype(dt) + p["bias"].astype(dt)


def init_norm(d, cfg):
    return init_rmsnorm(d, cfg) if cfg.norm == "rmsnorm" else init_layernorm(d, cfg)


def norm(p, x, cfg):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freq  # [...,S,1,half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    dt = pdtype(cfg)
    ks = _split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads, hd), dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), dt),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, d), dt,
                         scale=(cfg.n_heads * hd) ** -0.5),
    }


def _mask(q_pos, k_pos, mode: str, window: Optional[int]):
    """[..., Sq, Sk] boolean mask. q_pos/k_pos: [..., S] int32."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if mode == "bidir":
        m = kp >= 0
    else:
        # kp >= 0 also masks never-written cache slots / chunk padding
        m = (kp <= qp) & (kp >= 0)
    if window is not None:
        m = m & (kp > qp - window)
    return m


def _expand_kv(k, H: int):
    """GQA: repeat KV heads to H query heads (keeps the head dim intact so
    tensor parallelism shards 'heads' end-to-end with no resharding —
    q heads [g·G, g·G+G) map to kv head g, the standard grouping)."""
    K = k.shape[2]
    if K == H:
        return k
    return jnp.repeat(k, H // K, axis=2)


def _sdpa(q, k, v, mask, scale, kv_mode=None):
    """q:[B,Sq,H,D] k,v:[B,Sk,K,D] mask:[B,1,Sq,Sk] → [B,Sq,H,D].

    Training path expands KV to H heads (keeps the head dim intact for
    tensor parallelism).  Decode paths (``kv_mode`` set) use the grouped
    form instead — expanding a 32k-token cache 4× per layer would dominate
    decode HBM traffic; the tiny q reshape is free:

    ``kv_mode='seq'``: the KV cache's sequence dim is 'model'-sharded;
    logits keep it sharded and softmax lowers to partial max/sum + tiny
    all-reduces instead of gathering the cache.
    ``kv_mode='heads'``: kv_heads divide the model axis; grouped einsums
    shard on the K dim end-to-end."""
    B, Sq, H, D = q.shape
    if kv_mode is None:
        k = _expand_kv(k, H)
        v = _expand_kv(v, H)
        logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
        logits = constrain(logits * scale, "batch", "heads")
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)

    K = k.shape[2]
    qg = q.reshape(B, Sq, K, H // K, D)
    if kv_mode == "seq":
        k = constrain(k, "batch", "kv_seq", None, "head_dim")
        v = constrain(v, "batch", "kv_seq", None, "head_dim")
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits = logits * scale
    if kv_mode == "seq":
        logits = constrain(logits, "batch", None, None, None, "kv_seq")
    else:
        logits = constrain(logits, "batch", "kv_heads")
    logits = jnp.where(mask[:, :, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def _sdpa_chunked(q, k, v, q_pos, k_pos, mode, window, scale, chunk):
    """Blockwise-KV online-softmax attention (flash-style in pure JAX):
    peak memory O(Sq·chunk) instead of O(Sq·Sk)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    nch = -(-Sk // chunk)
    pad = nch * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(10 ** 9))
    kc = k.reshape(B, nch, chunk, H, D).swapaxes(0, 1)
    vc = v.reshape(B, nch, chunk, H, D).swapaxes(0, 1)
    pc = k_pos.reshape(B, nch, chunk).swapaxes(0, 1)

    def step(carry, xs):
        acc, m, l = carry
        kb, vb, pb = xs
        logits = jnp.einsum("bqhd,bshd->bhqs", q, kb).astype(jnp.float32)
        logits = logits * scale
        logits = constrain(logits, "batch", "heads")
        msk = _mask(q_pos, pb, mode, window)  # [B, Sq, chunk]
        logits = jnp.where(msk[:, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(vb.dtype), vb)
        acc = acc * corr[..., None].astype(acc.dtype) + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, D), v.dtype)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 2, 1, 3)


def attention(p, x, cfg: ModelConfig, *,
              mode: str = "causal",
              window: Optional[int] = None,
              positions: Optional[jnp.ndarray] = None,
              kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              kv_positions: Optional[jnp.ndarray] = None,
              cache: Optional[Dict] = None):
    """Self- or cross-attention.

    ``kv``       : precomputed (k, v) for cross-attention (whisper decoder).
    ``cache``    : {'k','v' [B,Sc,K,D], 'pos' scalar} decode-time KV cache —
                   writes the new token at ``pos % Sc`` (rolling buffer: for
                   SWA the cache is window-sized; for full attention it is
                   context-sized so the modulo never wraps).
    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    dt = x.dtype
    scale = hd ** -0.5
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    q = rope(q, positions, cfg.rope_theta) if kv is None else q

    new_cache = None
    if kv is not None:                     # cross-attention
        k, v = kv
        k_pos = kv_positions
        mode_eff, win = "bidir", None
    elif cache is not None:                # decode with KV cache
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
        k_new = rope(k_new, positions, cfg.rope_theta)
        Sc = cache["k"].shape[1]
        slot = (cache["pos"] % Sc).astype(jnp.int32)
        k = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        kvm = kv_cache_mode(cfg)
        if kvm == "seq":
            # keep the updated cache seq-sharded (the DUS must not gather)
            k = constrain(k, "batch", "kv_seq", None, "head_dim")
            v = constrain(v, "batch", "kv_seq", None, "head_dim")
        # cache slot i holds absolute position: reconstruct from pos
        idx = jnp.arange(Sc, dtype=jnp.int32)
        pos_now = cache["pos"].astype(jnp.int32)
        # absolute position stored in slot i (only valid if <= pos_now)
        abs_pos = pos_now - ((pos_now % Sc) - idx) % Sc
        k_pos = jnp.broadcast_to(abs_pos, (B, Sc))
        new_cache = {"k": k, "v": v, "pos": cache["pos"] + S}
        mode_eff, win = mode, window
    else:                                  # full-sequence self-attention
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
        k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions
        mode_eff, win = mode, window

    if cfg.attn_chunk and cache is None:
        out = _sdpa_chunked(q, k.astype(dt), v.astype(dt), positions, k_pos,
                            mode_eff, win, scale, cfg.attn_chunk)
    else:
        msk = _mask(positions, k_pos, mode_eff, win)[:, None]
        out = _sdpa(q, k.astype(dt), v.astype(dt), msk, scale,
                    kv_mode=kv_cache_mode(cfg) if cache is not None
                    else None)

    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return constrain(out, "batch", "seq", "embed"), new_cache


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    dt = pdtype(cfg)
    ks = _split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"wg": dense_init(ks[0], (d, cfg.d_ff), dt),
                "wu": dense_init(ks[1], (d, cfg.d_ff), dt),
                "wo": dense_init(ks[2], (cfg.d_ff, d), dt,
                                 scale=cfg.d_ff ** -0.5)}
    return {"wi": dense_init(ks[0], (d, cfg.d_ff), dt),
            "wo": dense_init(ks[1], (cfg.d_ff, d), dt,
                             scale=cfg.d_ff ** -0.5)}


def mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
        g = constrain(g, "batch", "seq", "ff")
        u = constrain(u, "batch", "seq", "ff")
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        h = constrain(h, "batch", "seq", "ff")
        if cfg.act == "sqrelu":
            r = jax.nn.relu(h)
            h = r * r
        else:
            h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    return constrain(out, "batch", "seq", "embed")


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig):
    dt = pdtype(cfg)
    ks = _split(key, 2)
    p = {"tok": dense_init(ks[0], (cfg.vocab, cfg.d_model), dt, scale=1.0)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), dt)
    return p


def embed(p, tokens, cfg: ModelConfig):
    out = p["tok"].astype(cdtype(cfg))[tokens]
    return constrain(out, "batch", "seq", "embed")


def unembed(p, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.tie_embeddings:
        # tied table is unit-scale; normalize logits by 1/sqrt(d) (gemma-
        # style) so init CE ≈ ln(vocab)
        w = p["tok"].astype(dt).T * (cfg.d_model ** -0.5)
    else:
        w = p["out"].astype(dt)
    out = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(out, "batch", "seq", "vocab")
