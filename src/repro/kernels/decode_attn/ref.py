"""Pure-jnp oracle for the flash decode-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def decode_attention_ref(q, k, v, lengths):
    """q: [B,H,hd]; k,v: [B,S,K,hd]; lengths: [B] valid KV entries.
    GQA grouping: q head h reads kv head h // (H//K).  → [B,H,hd]."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    mask = jnp.arange(S)[None] < lengths[:, None]          # [B,S]
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
