"""Flash decode attention as a Pallas TPU kernel (§Perf pair-2 'next
target'): one query token per sequence against a long KV cache, streamed
in sequence blocks with an online softmax — the cache is read exactly
once from HBM (the analytic decode floor), never materialized expanded or
transposed.

Grid: (B, S/bs) with the sequence dimension 'arbitrary' — the VMEM scratch
(running max m, normalizer l, accumulator acc in f32) persists across the
sequence steps of one batch row and is reset at s == 0; the final step
writes the normalized output block.  GQA handled by reshaping q to
[K, G, hd] so KV blocks are used directly (no head expansion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bs: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [K, G, hd] (pre-reshaped)
    kb = k_ref[0]                      # [bs, K, hd]
    vb = v_ref[0]
    length = len_ref[b]

    logits = jax.lax.dot_general(
        q.astype(jnp.float32), kb.astype(jnp.float32),
        (((2,), (2,)), ((0,), (1,)))) * scale        # [K, G, bs]
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    logits = jnp.where(pos < length, logits, -jnp.inf)

    m_prev = m_ref[...]                               # [K, G]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    # guard fully-masked blocks: exp(-inf - -inf) → use finite stand-in
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(logits - m_safe[..., None])           # [K, G, bs]
    p = jnp.where(pos < length, p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev),
                     jnp.exp(m_prev - m_safe), 0.0)   # [K, G]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, vb.astype(jnp.float32),
        (((2,), (0,)), ((0,), (1,))))                 # [K, G, hd]
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, block_s: int = 512,
                            interpret: bool = True):
    """q: [B,H,hd]; k,v: [B,S,K,hd]; lengths: [B] → [B,H,hd]."""
    B, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    bs = min(block_s, S)
    n_s = -(-S // bs)
    Sp = n_s * bs
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    qg = q.reshape(B, K, G, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, scale=scale),
        grid=(B, n_s),
        in_specs=[
            pl.BlockSpec((B,), lambda b, s: (0,)),            # lengths
            pl.BlockSpec((1, K, G, hd), lambda b, s: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, K, hd), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, K, hd), lambda b, s: (b, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, G, hd), lambda b, s: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),          # running max
            pltpu.VMEM((K, G), jnp.float32),          # normalizer
            pltpu.VMEM((K, G, hd), jnp.float32),      # accumulator
        ],
        interpret=interpret,
        name="flash_decode_attention",
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(lengths, qg, k, v)
    return out.reshape(B, H, hd)
