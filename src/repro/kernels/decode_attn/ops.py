"""jit'd wrapper for the flash decode-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from .decode_attn import decode_attention_pallas


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, lengths, block_s: int = 512,
                     interpret: bool = True):
    return decode_attention_pallas(q, k, v, lengths, block_s=block_s,
                                   interpret=interpret)
