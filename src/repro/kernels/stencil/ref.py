"""Pure-jnp oracle for the generated stencil kernels.

This re-exports the XLA lowering (``repro.core.lowering.lower_jax``), which
is the paper's reference-backend analogue.  Every Pallas template is
validated against it in ``tests/test_stencil_kernels.py`` over a sweep of
shapes, dtypes and templates.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import ir, lowering


def reference_apply(kernel: ir.StencilIR,
                    halos: Mapping[str, Tuple[int, ...]],
                    interior_shape: Tuple[int, ...],
                    arrays: Dict[str, jnp.ndarray],
                    scalars: Optional[Mapping[str, jnp.ndarray]] = None,
                    region=None) -> Dict[str, jnp.ndarray]:
    fn = lowering.lower_jax(kernel, halos, interior_shape, region)
    return fn(arrays, scalars or {})
