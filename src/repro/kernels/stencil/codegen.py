"""StencilIR → Pallas TPU kernel code generator (paper §4.5 templates).

Templates (paper Table 2), re-derived for the TPU memory hierarchy:

  gmem   — 3D/2D blocking; each tap is read by concatenating slices of the
           center block and its ±1 neighbor blocks (halo comes from
           *neighbor-block input refs*, the TPU analogue of reading global
           memory through the pipelined block fetch).
  smem   — 3D/2D blocking; the halo'd tile is materialized once in a VMEM
           scratch buffer and taps are static slices of it (the shared-
           memory analogue).
  f4     — gmem with lane-aligned blocks (last dim %128, 2nd-last %8): the
           VPU-vectorization analogue of float4.
  shift  — 2.5D streaming along axis 0: a rolling window of 2h+1 planes is
           carried through a fori_loop (mem_type 'registers' keeps it as
           loop-carried values ⇒ VREGs; 'vmem' streams planes straight from
           the VMEM tile).  Window advanced with jnp.roll.
  unroll — like shift but the window shift is statically unrolled
           (concatenate-rebuild ⇒ fixed VREG assignment).
  semi   — Semi-stencil [de la Cruz & Araya-Polo]: forward-scatter of each
           input plane into a rolling buffer of partial output planes; each
           input plane is touched exactly once, output planes complete with
           lag 2H.  Requires the kernel to be linear in its taps.

Halo handling: inputs are pre-padded by one full block per side (ops-level
wrapper below), so every neighbor-block index `g+1+δ` is in bounds and no
boundary conditionals appear inside the kernel — this is the consolidation
the paper's §6.2.1 'future work' asks for (one set of conditionals → zero).

Two execution modes share the template bodies:

  * ``lower_pallas`` — the original per-application path: pad inputs into
    block-padded layout, run one ``pallas_call``, merge outputs back into
    the unpadded arrays.  One ``jnp.pad`` per grid per application.
  * ``plan_pallas`` → :class:`PallasPlan` — the fused time-loop path.
    Lowering is split into a one-time *layout* stage (``to_padded``: one
    ``jnp.pad`` per grid per fusion window) and a per-invocation *kernel*
    stage (``step``: a single ``pallas_call`` whose outputs are written
    in-place in padded layout via ``input_output_aliases``; positions
    outside the true interior pass the old value through, so the grid halo
    survives across steps with no repacking).  Per-grid operands are
    deduplicated: each padded grid is passed once and fetched as a halo'd
    window (``pl.Unblocked`` BlockSpec) instead of once per neighbor
    delta.  With ``backend.time_block=k`` the kernel stage is *temporally
    blocked* (``_make_body_temporal``): windows carry k·h-deep expanded
    halos and one invocation advances k leapfrog steps in VMEM.  The
    k-step outputs are *double-buffered*: they alias dedicated
    destination operands instead of the read buffers, because the
    expanded windows overlap neighboring blocks' output interiors and the
    TPU grid runs sequentially (see ``PallasPlan``).  Per k steps each
    advanced grid thus costs one expanded-window read, one destination
    fetch and one block write — ~k× less traffic asymptotically, though
    halo growth + the destination fetch can make small depths a net loss
    (``TRAFFIC_COUNT`` tracks the modeled traffic honestly).

The expression evaluator is shared with the XLA lowering
(`repro.core.lowering.eval_expr`), so all backends execute the same IR.
"""
from __future__ import annotations

import collections
import functools
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import analysis, ir, lowering

DEFAULT_BLOCK = {2: (8, 128), 3: (8, 8, 128)}
STREAM_BLOCK = {2: (16, 128), 3: (16, 8, 128)}

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

# counts eager ``jnp.pad`` layout conversions per grid name; the fused path
# must show exactly one per grid per fusion window (tests/test_timeloop.py)
PAD_COUNT: collections.Counter = collections.Counter()

# modeled HBM traffic of the fused path, accumulated by the time-loop engine
# per executed window: grid-window reads, grid-block writes, and time steps
# covered.  With in-kernel temporal blocking (``time_block=k``) one
# read+write pair covers k steps, so reads/steps drops ~k× vs k=1.
TRAFFIC_COUNT: collections.Counter = collections.Counter()


def reset_pad_count() -> None:
    PAD_COUNT.clear()


def reset_traffic_count() -> None:
    TRAFFIC_COUNT.clear()


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def choose_block(user_block, template: str, ndim: int, region_shape,
                 min_halo=None):
    """Pick the BlockSpec tile.  ``min_halo`` (per-axis) forces the block to
    be at least that wide — temporal blocking fetches a ``k·h``-deep halo
    per side, and the window offset ``B − k·h`` must stay non-negative, so
    the block grows with the time depth (halo-growth geometry)."""
    if user_block is not None:
        if len(user_block) != ndim:
            raise ValueError(f"block must have {ndim} dims")
        return tuple(int(b) for b in user_block)
    base = (STREAM_BLOCK if template in ("shift", "unroll", "semi")
            else DEFAULT_BLOCK)[ndim]
    out = []
    for ax, b in enumerate(base):
        align = 128 if ax == ndim - 1 else 8
        bb = min(b, _round_up(region_shape[ax], align))
        if min_halo is not None and min_halo[ax] > bb:
            bb = _round_up(min_halo[ax], align)
        out.append(bb)
    return tuple(out)


def _deltas_for(tap_offsets: Sequence[Tuple[int, ...]]) -> List[Tuple[int, ...]]:
    """Block-neighbor offsets needed to cover these taps (star → axis
    neighbors only; box → the full needed corner set).  This is the
    shape-directed specialization at the heart of the paper."""
    ds = set()
    for offs in tap_offsets:
        axsets = []
        for o in offs:
            if o < 0:
                axsets.append((-1, 0))
            elif o > 0:
                axsets.append((0, 1))
            else:
                axsets.append((0,))
        ds.update(itertools.product(*axsets))
    return sorted(ds)


# ---------------------------------------------------------------------------
# tile assembly: paste neighbor blocks into a halo'd tile
# ---------------------------------------------------------------------------
def _paste_slices(delta, B, hg, ht):
    """(src_slice, dst_slice) per axis for pasting neighbor block `delta`
    into a tile with per-axis halo ht (ht >= hg; extra stays zero)."""
    src, dst = [], []
    for ax, d in enumerate(delta):
        b, h, t = B[ax], hg[ax], ht[ax]
        if d == -1:
            src.append(slice(b - h, b))
            dst.append(slice(t - h, t))
        elif d == 0:
            src.append(slice(0, b))
            dst.append(slice(t, t + b))
        else:
            src.append(slice(0, h))
            dst.append(slice(t + b, t + b + h))
    return tuple(src), tuple(dst)


def _assemble_tile(read_block, g, deltas, B, hg, ht, dtype):
    tile_shape = tuple(B[ax] + 2 * ht[ax] for ax in range(len(B)))
    tile = jnp.zeros(tile_shape, dtype)
    for d in deltas:
        src, dst = _paste_slices(d, B, hg, ht)
        tile = tile.at[dst].set(read_block(g, d)[src])
    return tile


# ---------------------------------------------------------------------------
# statement execution shared by templates
# ---------------------------------------------------------------------------
def _exec_statements(kernel: ir.StencilIR, tap_read, scalars, shape, dtype):
    """Run kernel statements; returns {output grid: value}.

    ``tap_read(grid, offsets)`` reads *old* values; center reads of grids
    written by an earlier statement return the new value (sequential
    multi-statement semantics, checked by analysis.check_read_after_write).
    """
    env: Dict[str, jnp.ndarray] = {}
    locals_env: Dict[str, jnp.ndarray] = {}

    def read(g, offs):
        if g in env and not any(offs):
            return env[g]
        return tap_read(g, offs)

    for stmt in kernel.body:
        val = lowering.eval_expr(stmt.expr, read, scalars, locals_env)
        if isinstance(stmt, ir.LocalDef):
            locals_env[stmt.name] = val
        else:
            env[stmt.grid] = jnp.broadcast_to(jnp.asarray(val, dtype), shape)
    return env


# ---------------------------------------------------------------------------
# template kernel bodies
# ---------------------------------------------------------------------------
def _make_body_blocked(kernel, info, spec, use_scratch: bool):
    """gmem / f4 (use_scratch=False) and smem (use_scratch=True) bodies."""
    B, gh, ndim = spec["B"], spec["gh"], spec["ndim"]
    in_index, scal_names, out_grids, dtype = (
        spec["in_index"], spec["scal_names"], spec["out_grids"], spec["dtype"])

    def body(*refs):
        n_in = len(in_index)
        in_refs = refs[:n_in]
        scal_refs = refs[n_in:n_in + len(scal_names)]
        out_refs = refs[n_in + len(scal_names):n_in + len(scal_names) + len(out_grids)]
        scratch = refs[n_in + len(scal_names) + len(out_grids):]

        loaded: Dict = {}

        def read_block(g, d):
            key = (g, d)
            if key not in loaded:
                loaded[key] = in_refs[in_index[key]][...]
            return loaded[key]

        scalars = {n: r[0, 0] for n, r in zip(scal_names, scal_refs)}

        if use_scratch:
            tiles = {}
            for gi, g in enumerate(spec["in_grids"]):
                sref = scratch[gi]
                sref[...] = jnp.zeros(sref.shape, dtype)
                for d in spec["deltas"][g]:
                    src, dst = _paste_slices(d, B, gh[g], gh[g])
                    sref[dst] = read_block(g, d)[src]
                tiles[g] = sref

            def tap_read(g, offs):
                h = gh[g]
                idx = tuple(slice(h[ax] + offs[ax], h[ax] + offs[ax] + B[ax])
                            for ax in range(ndim))
                return tiles[g][idx]
        else:
            def tap_read(g, offs):
                # concat-of-neighbor-block-slices, axis by axis
                def rec(axis, delta):
                    if axis == ndim:
                        return read_block(g, delta)
                    o = offs[axis]
                    if o == 0:
                        return rec(axis + 1, delta + (0,))
                    lo = rec(axis + 1, delta + ((-1,) if o < 0 else (0,)))
                    hi = rec(axis + 1, delta + ((0,) if o < 0 else (1,)))
                    cut = B[axis] + o if o < 0 else o
                    a = lax.slice_in_dim(lo, cut, B[axis], axis=axis)
                    b = lax.slice_in_dim(hi, 0, cut, axis=axis)
                    return lax.concatenate([a, b], dimension=axis)
                return rec(0, ())

        env = _exec_statements(kernel, tap_read, scalars, B, dtype)
        for g, oref in zip(out_grids, out_refs):
            oref[...] = env[g]

    return body


def _semi_linearize(kernel):
    """Linearize for the semi template: out_grid -> ([(grid, offs,
    coeff_expr)], const_expr), plus the streaming halo H.  Coefficients may
    contain center-only taps (coefficient *fields*, e.g. vp² in acoustic
    ISO) — evaluated per output plane by the streaming loop."""
    lin = {}
    written = set()
    for a in analysis.inline_locals(kernel):
        terms, const = analysis.linearize(a.expr, allow_center_fields=True)
        for t in ir.StencilIR(kernel.name, kernel.ndim, kernel.grid_params,
                              kernel.scalar_params, (a,)).taps():
            if t.grid in written:
                raise ValueError("semi template does not support reading "
                                 "a previously-written grid")
        written.add(a.grid)
        lin[a.grid] = ([(g, offs, c) for (g, offs), c in terms.items()],
                       const)
    H = max((abs(offs[0]) for terms, _ in lin.values()
             for _, offs, _ in terms), default=0)
    return lin, H


def _stream_halo(kernel, spec, variant):
    """(lin, H) for a streaming body: the x-axis window halo and, for semi,
    the linearized form."""
    if variant == "semi":
        return _semi_linearize(kernel)
    gh, in_grids = spec["gh"], spec["in_grids"]
    return None, max((gh[g][0] for g in in_grids), default=0)


def _stream_outputs(kernel, spec, tiles, scalars, *, variant: str,
                    mem_type: str, H: int, lin):
    """Run the 2.5D streaming loop over per-grid x-column ``tiles`` (x-halo
    ``H``, per-grid y/z halo) and return the output blocks (shape B), one
    per ``spec['out_grids']`` entry.  Shared by the per-application bodies
    (tiles assembled from neighbor-block refs) and the fused bodies (tiles
    sliced straight from the halo'd input window)."""
    B, gh, ndim = spec["B"], spec["gh"], spec["ndim"]
    out_grids, dtype = spec["out_grids"], spec["dtype"]
    in_grids = spec["in_grids"]
    plane_shape = tuple(B[1:])
    bx = B[0]

    def plane(g, t):
        """Input plane at tile-x index t, full y/z halo extent."""
        return lax.dynamic_slice_in_dim(tiles[g], t, 1, axis=0)[0]

    def center_yz(g, arr, offs_yz):
        h = gh[g][1:]
        idx = tuple(slice(h[ax] + offs_yz[ax], h[ax] + offs_yz[ax] + B[1 + ax])
                    for ax in range(ndim - 1))
        return arr[idx]

    if variant == "semi":
        def field_read_at(tile_idx):
            """Read center-only coefficient-field taps at the plane with
            the given (dynamic) tile-x index."""
            def tr(g, offs):
                return center_yz(g, plane(g, tile_idx),
                                 tuple(offs[1:]))
            return tr

        def step(t, carry):
            # Invariant: at start of step t, P[k] holds the partial sum
            # for output plane (t - 2H + k).  Input plane at tile-x
            # index t is region plane x_in = t - H; its term (g,offs=d)
            # contributes coeff(x_in - d) * u[x_in] to out plane
            # o = x_in - d (slot H - d, coeff-field tile idx t - d,
            # clamped reads only ever reach never-emitted planes).
            Ps, outs = carry
            newPs, newouts = [], []
            for og, P, out in zip(out_grids, Ps, outs):
                terms, const = lin[og]
                for (g, offs, c) in terms:
                    d = offs[0]
                    cval = lowering.eval_expr(
                        c, field_read_at(t - d), scalars, {})
                    contrib = cval * center_yz(g, plane(g, t), offs[1:])
                    P = P.at[H - d].add(contrib)
                cv = lowering.eval_expr(
                    const, field_read_at(t - H), scalars, {})
                done = P[0] + cv
                o = t - 2 * H
                out = lax.cond(
                    o >= 0,
                    lambda out=out, done=done, o=o:
                        lax.dynamic_update_slice_in_dim(
                            out, done[None], o, axis=0),
                    lambda out=out: out)
                P = jnp.concatenate(
                    [P[1:], jnp.zeros((1,) + plane_shape, dtype)], axis=0)
                newPs.append(P)
                newouts.append(out)
            return tuple(newPs), tuple(newouts)

        Ps0 = tuple(jnp.zeros((2 * H + 1,) + plane_shape, dtype)
                    for _ in out_grids)
        outs0 = tuple(jnp.zeros(B, dtype) for _ in out_grids)
        _, outs = lax.fori_loop(0, bx + 2 * H, step, (Ps0, outs0))
        return outs

    # ---- shift / unroll ------------------------------------------------
    win_len = {g: 2 * gh[g][0] + 1 for g in in_grids}

    if mem_type == "vmem":
        # stream straight from the VMEM tile: taps = dynamic plane slices
        def compute_plane(t):
            def tap_read(g, offs):
                # tile x index of region plane t+offs[0]: t + H + offs[0]
                p = plane(g, t + H + offs[0])
                return center_yz(g, p, offs[1:])
            return _exec_statements(kernel, tap_read, scalars,
                                    plane_shape, dtype)

        def step(t, outs):
            env = compute_plane(t)
            return tuple(
                lax.dynamic_update_slice_in_dim(out, env[g][None], t, axis=0)
                for g, out in zip(out_grids, outs))

        outs0 = tuple(jnp.zeros(B, dtype) for _ in out_grids)
        return lax.fori_loop(0, bx, step, outs0)

    # mem_type == 'registers': rolling loop-carried window per grid.
    # Invariant: after `advance` at step t, window slot k holds the
    # plane at region coord t - hg0 + k (tile-x index t - hg0 + k + H).
    def init_window(g):
        n = win_len[g]
        hg0 = gh[g][0]
        planes = [jnp.zeros(tiles[g].shape[1:], dtype)]
        for k in range(1, n):
            planes.append(plane(g, H - hg0 + k - 1))
        return jnp.stack(planes, axis=0)

    def advance(W, new_plane):
        if variant == "unroll":
            return jnp.concatenate([W[1:], new_plane[None]], axis=0)
        W = jnp.roll(W, -1, axis=0)
        return W.at[-1].set(new_plane)

    def step(t, carry):
        Ws, outs = carry
        # newest slot holds region plane t + hg0 → tile-x index t+hg0+H
        Ws2 = tuple(advance(W, plane(g, t + gh[g][0] + H))
                    for g, W in zip(in_grids, Ws))

        def tap_read(g, offs):
            W = Ws2[in_grids.index(g)]
            slot = gh[g][0] + offs[0]
            return center_yz(g, W[slot], offs[1:])

        env = _exec_statements(kernel, tap_read, scalars, plane_shape, dtype)
        outs = tuple(
            lax.dynamic_update_slice_in_dim(out, env[g][None], t, axis=0)
            for g, out in zip(out_grids, outs))
        return Ws2, outs

    Ws0 = tuple(init_window(g) for g in in_grids)
    outs0 = tuple(jnp.zeros(B, dtype) for _ in out_grids)
    _, outs = lax.fori_loop(0, bx, step, (Ws0, outs0))
    return outs


def _make_body_streaming(kernel, info, spec, *, variant: str,
                         mem_type: str, prefetch: bool):
    """shift / unroll / semi bodies: 2.5D streaming along axis 0."""
    B, gh = spec["B"], spec["gh"]
    in_index, scal_names, out_grids, dtype = (
        spec["in_index"], spec["scal_names"], spec["out_grids"], spec["dtype"])
    in_grids = spec["in_grids"]
    lin, H = _stream_halo(kernel, spec, variant)

    def body(*refs):
        n_in = len(in_index)
        in_refs = refs[:n_in]
        scal_refs = refs[n_in:n_in + len(scal_names)]
        out_refs = refs[n_in + len(scal_names):n_in + len(scal_names) + len(out_grids)]

        scalars = {n: r[0, 0] for n, r in zip(scal_names, scal_refs)}

        def read_block(g, d):
            return in_refs[in_index[(g, d)]][...]

        # assemble per-grid x-column tiles with x-halo H (>= per-grid halo;
        # extra planes stay zero, harmless for the linear scatter)
        tiles = {}
        for g in in_grids:
            ht = (H,) + tuple(gh[g][1:])
            tiles[g] = _assemble_tile(read_block, g, spec["deltas"][g],
                                      B, gh[g], ht, dtype)

        outs = _stream_outputs(kernel, spec, tiles, scalars, variant=variant,
                               mem_type=mem_type, H=H, lin=lin)
        for out, oref in zip(outs, out_refs):
            oref[...] = out

    return body


# ---------------------------------------------------------------------------
# top-level lowering
# ---------------------------------------------------------------------------
def lower_pallas(kernel: ir.StencilIR,
                 halos: Dict[str, Tuple[int, ...]],
                 interior_shape: Tuple[int, ...],
                 region,
                 backend):
    """Build ``fn(arrays: dict, scalars: dict) -> dict`` running the kernel
    through a generated Pallas TPU kernel (interpret=True executes the body
    in Python on CPU)."""
    info = analysis.analyze(kernel)
    ndim = kernel.ndim
    if ndim not in (2, 3):
        raise ValueError("pallas backend supports 2D and 3D stencils")
    if region is None:
        region = tuple((0, s) for s in interior_shape)
    R = tuple(e - b for b, e in region)
    if int(getattr(backend, "time_block", 1) or 1) > 1:
        raise ValueError(
            "time_block > 1 is a fused time-loop feature (st.timeloop / "
            "plan_pallas); the per-application path advances one step")
    template = backend.template
    B = choose_block(backend.block, template, ndim, R)

    in_grids = info.input_grids
    out_grids = info.output_grids
    gh = {g: info.halo_per_grid.get(g, (0,) * ndim) for g in in_grids}
    for g in in_grids:
        for ax in range(ndim):
            if gh[g][ax] > B[ax]:
                raise ValueError(
                    f"halo {gh[g][ax]} exceeds block {B[ax]} on axis {ax}; "
                    "increase block size")
    if template == "f4":
        if B[-1] % 128 or (ndim >= 2 and B[-2] % 8):
            raise ValueError("f4 template requires lane-aligned blocks "
                             "(last dim %128, 2nd-last %8)")

    mem_type = backend.mem_type
    if mem_type is None:
        mem_type = "registers" if info.shape in ("star", "point") else "vmem"

    nb = tuple(-(-R[ax] // B[ax]) for ax in range(ndim))

    # taps per grid → needed block-neighbor deltas
    taps_by_grid: Dict[str, List[Tuple[int, ...]]] = {g: [] for g in in_grids}
    for t in kernel.taps():
        taps_by_grid[t.grid].append(t.offsets)
    deltas = {g: _deltas_for(taps_by_grid[g]) for g in in_grids}

    def _neighbor_index_map(d):
        def imap(*gi):
            return tuple(gi[ax] + 1 + d[ax] for ax in range(ndim))
        return imap

    in_index: Dict = {}
    in_specs = []
    for g in in_grids:
        for d in deltas[g]:
            in_index[(g, d)] = len(in_specs)
            in_specs.append(pl.BlockSpec(B, _neighbor_index_map(d)))
    scal_names = [n for n, _ in kernel.scalar_params]
    for _ in scal_names:
        in_specs.append(pl.BlockSpec((1, 1), lambda *gi: (0, 0)))

    out_specs = [pl.BlockSpec(B, lambda *gi: gi) for _ in out_grids]

    spec = dict(B=B, gh=gh, ndim=ndim, in_index=in_index,
                scal_names=scal_names, out_grids=out_grids,
                in_grids=in_grids, deltas=deltas, dtype=None)

    grid = nb

    def fn(arrays: Dict[str, jnp.ndarray], scalars: Dict[str, jnp.ndarray]):
        dtype = arrays[out_grids[0]].dtype
        spec_d = dict(spec, dtype=dtype)

        if template in ("gmem", "f4"):
            body = _make_body_blocked(kernel, info, spec_d, use_scratch=False)
            scratch_shapes = []
        elif template == "smem":
            body = _make_body_blocked(kernel, info, spec_d, use_scratch=True)
            scratch_shapes = [
                pltpu.VMEM(tuple(B[ax] + 2 * gh[g][ax] for ax in range(ndim)),
                           dtype)
                for g in in_grids]
        else:
            body = _make_body_streaming(kernel, info, spec_d,
                                        variant=template, mem_type=mem_type,
                                        prefetch=backend.prefetch)
            scratch_shapes = []

        # ---- pad inputs: one extra block per side + halo placement -------
        ops = []
        for g in in_grids:
            arr = arrays[g]
            halo_arr = halos[g]
            h = gh[g]
            for ax in range(ndim):
                if halo_arr[ax] + region[ax][0] < h[ax]:
                    raise ValueError(
                        f"grid '{g}' halo {halo_arr[ax]} too small for "
                        f"kernel halo {h[ax]} at region {region[ax]}")
            sl = tuple(slice(halo_arr[ax] + region[ax][0] - h[ax],
                             halo_arr[ax] + region[ax][1] + h[ax])
                       for ax in range(ndim))
            W = arr[sl]
            pads = []
            for ax in range(ndim):
                before = B[ax] - h[ax]
                total = (nb[ax] + 2) * B[ax]
                pads.append((before, total - before - W.shape[ax]))
            P = jnp.pad(W, pads)
            for d in deltas[g]:
                ops.append(P)
        for n in scal_names:
            ops.append(jnp.asarray(scalars[n], jnp.float32).reshape(1, 1))

        call = pl.pallas_call(
            body,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=[jax.ShapeDtypeStruct(
                tuple(nb[ax] * B[ax] for ax in range(ndim)), dtype)
                for _ in out_grids],
            scratch_shapes=scratch_shapes,
            interpret=backend.interpret,
            name=f"stencil_{kernel.name}_{template}",
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary",) * ndim),
        )
        outs = call(*ops)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]

        result = dict(arrays)
        for g, O in zip(out_grids, outs):
            full = arrays[g]
            halo_arr = halos[g]
            idx = tuple(slice(halo_arr[ax] + region[ax][0],
                              halo_arr[ax] + region[ax][1])
                        for ax in range(ndim))
            cut = tuple(slice(0, R[ax]) for ax in range(ndim))
            result[g] = full.at[idx].set(O[cut])
        return result

    return fn


# ---------------------------------------------------------------------------
# fused time-loop path: one-time layout stage + per-step kernel stage
# ---------------------------------------------------------------------------
def _valid_mask(B, R, ndim, ext=None):
    """Mask of positions that belong to the true interior, over the block
    extended by ``ext`` per side (temporal sub-steps compute shrinking
    shells that reach below coordinate 0 and past R; the block may also
    overhang the interior when R is not a block multiple)."""
    ext = ext or (0,) * ndim
    shape = tuple(B[ax] + 2 * ext[ax] for ax in range(ndim))
    mask = None
    for ax in range(ndim):
        coord = (pl.program_id(ax) * B[ax] - ext[ax]
                 + lax.broadcasted_iota(jnp.int32, shape, ax))
        m = jnp.logical_and(coord >= 0, coord < R[ax])
        mask = m if mask is None else jnp.logical_and(mask, m)
    return mask


def _make_body_fused(kernel, info, spec, *, template: str, mem_type: str):
    """Persistent-layout step body: one halo'd *window* ref per grid
    (deduplicated operands), outputs written in padded layout with
    pass-through of the old value outside the true interior (preserves the
    grid halo and the padding across fused steps — no repacking)."""
    B, gh, ndim, R = spec["B"], spec["gh"], spec["ndim"], spec["R"]
    opnd_index, scal_names, out_grids, dtype = (
        spec["opnd_index"], spec["scal_names"], spec["out_grids"],
        spec["dtype"])
    in_grids = spec["in_grids"]
    streaming = template in ("shift", "unroll", "semi")
    lin = H = None
    if streaming:
        lin, H = _stream_halo(kernel, spec, template)

    def body(*refs):
        n_in = len(opnd_index)
        in_refs = refs[:n_in]
        scal_refs = refs[n_in:n_in + len(scal_names)]
        out_refs = refs[n_in + len(scal_names):]

        scalars = {n: r[0, 0] for n, r in zip(scal_names, scal_refs)}
        loaded: Dict = {}

        def win(g):
            if g not in loaded:
                loaded[g] = in_refs[opnd_index[g]][...]
            return loaded[g]

        if streaming:
            # tiles sliced straight from the fetched window; zero-extend the
            # x-halo to the streaming halo H, matching the per-application
            # tile assembly (extra planes stay zero for the linear scatter)
            tiles = {}
            for g in in_grids:
                w = win(g)
                if H == gh[g][0]:
                    tiles[g] = w
                else:
                    pad0 = H - gh[g][0]
                    t = jnp.zeros((B[0] + 2 * H,) + w.shape[1:], dtype)
                    tiles[g] = t.at[pad0:pad0 + w.shape[0]].set(w)
            env_vals = _stream_outputs(kernel, spec, tiles, scalars,
                                       variant=template, mem_type=mem_type,
                                       H=H, lin=lin)
            env = dict(zip(out_grids, env_vals))
        else:
            def tap_read(g, offs):
                h = gh[g]
                idx = tuple(slice(h[ax] + offs[ax], h[ax] + offs[ax] + B[ax])
                            for ax in range(ndim))
                return win(g)[idx]

            env = _exec_statements(kernel, tap_read, scalars, B, dtype)

        mask = _valid_mask(B, R, ndim)
        for g, oref in zip(out_grids, out_refs):
            # outside the interior keep the old value (win(g) is the bare
            # center block: fused mode requires center-only taps of outputs)
            oref[...] = jnp.where(mask, env[g], win(g))

    return body


def _make_body_temporal(kernel, info, spec, *, template: str, mem_type: str,
                        time_block: int, swap):
    """In-kernel temporal blocking: advance ``time_block`` leapfrog steps
    per kernel invocation (paper-style time skewing brought inside the
    Pallas block, cf. ``distributed._lower_time_skewed`` at pod level).

    Each operand grid is fetched once as a window with an expanded halo
    (``k·h`` for the swap pair, ``(k−1)·h + h_g`` for coefficient grids)
    and kept as a VMEM-resident *frame*.  Sub-step ``j`` evaluates the
    template body over the block extended by ``(k−1−j)·h`` per side — the
    valid region shrinks by ``h`` per step, shells being recomputed
    redundantly by neighboring blocks — and writes the leapfrog buffers
    alternately (sub-step 0 → ``swap[0]``'s buffer, 1 → ``swap[1]``'s, …),
    which is exactly the per-step write+rotate sequence expressed in buffer
    space.  Outside the true interior every sub-step passes the frame's old
    value through, so grid-halo cells keep their original values and feed
    later sub-steps unchanged (per-step boundary semantics).  Only the
    final ``B`` interior of each swap frame is written back — HBM sees one
    read and one write per grid per ``k`` steps.

    The write-back is *double-buffered*: outputs alias dedicated
    destination operands (``refs[n_in:n_in+len(step_out)]``, never read
    here), NOT the window operands.  The expanded windows reach ``k·h``
    into neighboring blocks' output interiors, and on real TPU the grid
    runs sequentially — aliasing the read buffers in place would let
    later blocks fetch halo data that earlier blocks already advanced
    ``k`` steps (interpret mode reads inputs functionally and hides the
    hazard).
    """
    B, gh, ndim, R = spec["B"], spec["gh"], spec["ndim"], spec["R"]
    opnd_index, scal_names, dtype = (
        spec["opnd_index"], spec["scal_names"], spec["dtype"])
    in_grids = spec["in_grids"]
    wf, hvec = spec["wf"], spec["hvec"]
    step_out = spec["step_out_grids"]          # (written, other) buffers
    k = time_block
    written, other = swap
    streaming = template in ("shift", "unroll", "semi")
    lin = H = None
    if streaming:
        lin, H = _stream_halo(kernel, spec, template)

    def body(*refs):
        n_in = len(opnd_index)
        in_refs = refs[:n_in]
        # destination operands (aliased to the outputs) sit between the
        # read windows and the scalars; their values are never read
        n_dst = len(step_out)
        scal_refs = refs[n_in + n_dst:n_in + n_dst + len(scal_names)]
        out_refs = refs[n_in + n_dst + len(scal_names):]

        scalars = {n: r[0, 0] for n, r in zip(scal_names, scal_refs)}
        frames = {g: in_refs[i][...] for g, i in opnd_index.items()}

        for j in range(k):
            ext = tuple((k - 1 - j) * hvec[ax] for ax in range(ndim))
            S = tuple(B[ax] + 2 * ext[ax] for ax in range(ndim))
            # leapfrog in buffer space: IR names ↔ buffers alternate
            nm = {written: written, other: other} if j % 2 == 0 \
                else {written: other, other: written}

            if streaming:
                tiles = {}
                for g in in_grids:
                    buf = nm.get(g, g)
                    w, h = wf[buf], gh[g]
                    tile = frames[buf][tuple(
                        slice(w[ax] - ext[ax] - h[ax],
                              w[ax] + B[ax] + ext[ax] + h[ax])
                        for ax in range(ndim))]
                    if H > h[0]:
                        # zero-extend the x-halo to the streaming halo H,
                        # matching the single-step fused body
                        pad0 = H - h[0]
                        t = jnp.zeros((S[0] + 2 * H,) + tile.shape[1:], dtype)
                        tile = t.at[pad0:pad0 + tile.shape[0]].set(tile)
                    tiles[g] = tile
                env_vals = _stream_outputs(kernel, dict(spec, B=S), tiles,
                                           scalars, variant=template,
                                           mem_type=mem_type, H=H, lin=lin)
                val = env_vals[0]
            else:
                def tap_read(g, offs, ext=ext, S=S, nm=nm):
                    buf = nm.get(g, g)
                    w = wf[buf]
                    idx = tuple(
                        slice(w[ax] - ext[ax] + offs[ax],
                              w[ax] - ext[ax] + offs[ax] + S[ax])
                        for ax in range(ndim))
                    return frames[buf][idx]

                env = _exec_statements(kernel, tap_read, scalars, S, dtype)
                val = env[written]

            tgt = nm[written]
            w = wf[tgt]
            region = tuple(slice(w[ax] - ext[ax], w[ax] + B[ax] + ext[ax])
                           for ax in range(ndim))
            # outside the true interior the buffer keeps its original
            # (grid-halo) value — re-imposed every sub-step so shells never
            # leak boundary garbage into later sub-steps
            mask = _valid_mask(B, R, ndim, ext)
            frames[tgt] = frames[tgt].at[region].set(
                jnp.where(mask, val, frames[tgt][region]))

        for g, oref in zip(step_out, out_refs):
            w = wf[g]
            oref[...] = frames[g][tuple(slice(w[ax], w[ax] + B[ax])
                                        for ax in range(ndim))]

    return body


class PallasPlan:
    """Split Pallas lowering for fused time stepping.

    ``to_padded``  — one-time layout stage: convert each participating grid
                     to the persistent block-padded layout (ONE ``jnp.pad``
                     per grid; counted in ``PAD_COUNT``).
    ``step``       — kernel stage: one ``pallas_call`` that reads halo'd
                     windows (one deduplicated operand per grid) and
                     writes each output grid in-place in padded layout
                     (``input_output_aliases``), passing the old value
                     through outside the interior so halos survive.  The
                     in-place aliasing is only legal because outputs are
                     restricted to center-only taps: every read of an
                     aliased buffer stays inside the block the same
                     program instance writes.  With
                     ``backend.time_block=k`` one call advances k leapfrog
                     steps on k·h-expanded windows and writes *both* swap
                     buffers back — but the k·h windows overlap
                     neighboring blocks' output interiors, so the outputs
                     are double-buffered: they alias dedicated
                     destination operands (``make_spares``), never the
                     read windows (see ``_make_body_temporal``).
    ``from_padded``— write padded interiors back into full (grid-halo'd)
                     arrays at a fusion boundary.

    Grids named in ``swap`` share a common layout halo so their buffers can
    be rotated between steps without re-laying-out.
    """

    def __init__(self, kernel: ir.StencilIR,
                 halos: Dict[str, Tuple[int, ...]],
                 interior_shape: Tuple[int, ...],
                 backend,
                 swap: Optional[Tuple[str, str]] = None):
        info = analysis.analyze(kernel)
        ndim = kernel.ndim
        if ndim not in (2, 3):
            raise ValueError("pallas backend supports 2D and 3D stencils")
        template = backend.template
        R = tuple(interior_shape)
        k = int(getattr(backend, "time_block", 1) or 1)
        if k < 1:
            raise ValueError("time_block must be >= 1")
        hvec = tuple(info.halo) if info.halo else (0,) * ndim
        in_grids = info.input_grids
        out_grids = info.output_grids
        if k > 1:
            if swap is None:
                raise ValueError(
                    "time_block > 1 requires a swap pair: the in-kernel "
                    "sub-steps are the leapfrog write+rotate sequence")
            if len(out_grids) != 1 or out_grids[0] != swap[0]:
                raise ValueError(
                    "time_block > 1 supports single-output kernels writing "
                    f"swap[0] (outputs: {out_grids}, swap: {swap})")
        B = choose_block(backend.block, template, ndim, R,
                         min_halo=tuple(k * h for h in hvec) if k > 1
                         else None)
        opnd_grids = tuple(g for g in kernel.grid_params
                           if g in set(in_grids) | set(out_grids))
        gh = {g: info.halo_per_grid.get(g, (0,) * ndim) for g in opnd_grids}
        for g in out_grids:
            if any(gh[g]):
                raise ValueError(
                    f"fused time stepping requires center-only taps of the "
                    f"output grid '{g}' (its padded buffer is written "
                    "in-place while neighbors still read it)")
        for g in in_grids:
            for ax in range(ndim):
                if gh[g][ax] > B[ax]:
                    raise ValueError(
                        f"halo {gh[g][ax]} exceeds block {B[ax]} on axis "
                        f"{ax}; increase block size")
        # expanded window (frame) halo per operand: the swap pair trades
        # buffers between sub-steps so both carry the full k·h; coefficient
        # grids are only read while the valid region is ≥ (k−1−j)·h wide
        if k > 1:
            wf = {g: tuple(k * hvec[ax] for ax in range(ndim))
                  if g in swap
                  else tuple((k - 1) * hvec[ax] + gh[g][ax]
                             for ax in range(ndim))
                  for g in opnd_grids}
        else:
            wf = dict(gh)
        for g in opnd_grids:
            for ax in range(ndim):
                if wf[g][ax] > B[ax]:
                    raise ValueError(
                        f"time_block={k}: expanded halo {wf[g][ax]} exceeds "
                        f"block {B[ax]} on axis {ax} (need k·h <= block "
                        "extent; reduce time_block or increase block)")
        if template == "f4" and (B[-1] % 128 or B[-2] % 8):
            raise ValueError("f4 template requires lane-aligned blocks "
                             "(last dim %128, 2nd-last %8)")
        mem_type = backend.mem_type
        if mem_type is None:
            mem_type = "registers" if info.shape in ("star", "point") \
                else "vmem"

        # layout halo: swap partners trade buffers between steps, so they
        # must share one padded geometry (the elementwise max of their taps)
        hw = dict(gh)
        if swap is not None:
            a, b = swap
            if a not in opnd_grids or b not in opnd_grids:
                raise ValueError(f"swap grids {swap} must appear in kernel")
            m = tuple(max(gh[a][ax], gh[b][ax]) for ax in range(ndim))
            hw[a] = hw[b] = m
        for g in opnd_grids:
            for ax in range(ndim):
                if halos[g][ax] < hw[g][ax]:
                    raise ValueError(
                        f"grid '{g}' halo {halos[g][ax]} too small for "
                        f"layout halo {hw[g][ax]} on axis {ax}")

        nb = tuple(-(-R[ax] // B[ax]) for ax in range(ndim))
        padded_shape = tuple((nb[ax] + 2) * B[ax] for ax in range(ndim))
        scal_names = [n for n, _ in kernel.scalar_params]

        def _window_map(w):
            def imap(*gi):
                return tuple(gi[ax] * B[ax] + B[ax] - w[ax]
                             for ax in range(ndim))
            return imap

        # per-invocation outputs: with time_block > 1 both swap buffers are
        # advanced in-kernel, so both are written back (aliased in-place)
        step_out = tuple(out_grids) if k == 1 else tuple(swap)

        in_specs = []
        for g in opnd_grids:
            w = wf[g]
            in_specs.append(pl.BlockSpec(
                tuple(B[ax] + 2 * w[ax] for ax in range(ndim)),
                _window_map(w), indexing_mode=pl.Unblocked()))
        if k > 1:
            # double-buffered outputs: the k·h-expanded windows reach into
            # neighboring blocks' output interiors, and the TPU grid runs
            # sequentially — aliasing the read buffers in place would let
            # later blocks fetch halos already advanced k steps.  Outputs
            # therefore alias dedicated block-sized destination operands
            # (one per advanced grid, never read by the body); the engine
            # ping-pongs them against the read buffers between invocations.
            for _ in step_out:
                in_specs.append(pl.BlockSpec(
                    B, lambda *gi: tuple(g + 1 for g in gi)))
            aliases = {len(opnd_grids) + oi: oi
                       for oi in range(len(step_out))}
        else:
            # k=1 may alias in place: outputs are center-only-tapped, so
            # no program instance reads outside the block it writes
            aliases = {opnd_grids.index(g): oi
                       for oi, g in enumerate(step_out)}
        for _ in scal_names:
            in_specs.append(pl.BlockSpec((1, 1), lambda *gi: (0, 0)))
        out_specs = [pl.BlockSpec(B, lambda *gi: tuple(g + 1 for g in gi))
                     for _ in step_out]

        self.kernel, self.info, self.backend = kernel, info, backend
        self.halos = {g: tuple(halos[g]) for g in opnd_grids}
        self.template, self.mem_type = template, mem_type
        self.ndim, self.R, self.B, self.nb = ndim, R, B, nb
        self.gh, self.hw, self.swap = gh, hw, swap
        self.time_block, self.hvec, self.wf = k, hvec, wf
        self.in_grids, self.out_grids = in_grids, out_grids
        self.step_out_grids = step_out
        self.opnd_grids, self.scal_names = opnd_grids, scal_names
        self.padded_shape = padded_shape
        self._in_specs, self._out_specs = in_specs, out_specs
        self._aliases = aliases
        self._calls: Dict = {}
        # grids whose padded buffers change across steps (need write-back)
        self.touched = tuple(g for g in opnd_grids
                             if g in set(out_grids) | set(swap or ()))

    # -- traffic model -----------------------------------------------------
    @property
    def _dest_fetches(self) -> int:
        """Destination-operand block fetches per invocation: the k>1
        double-buffered outputs alias dedicated operands whose blocks are
        DMA'd in like any input even though the body never reads them."""
        return len(self.step_out_grids) if self.time_block > 1 else 0

    @property
    def grid_reads_per_step(self) -> float:
        """Grid HBM fetches per time step (each invocation reads one window
        per operand grid plus the destination blocks, and covers
        ``time_block`` steps)."""
        return ((len(self.opnd_grids) + self._dest_fetches)
                / self.time_block)

    @property
    def grid_writes_per_step(self) -> float:
        """Grid-block HBM writes per time step."""
        return len(self.step_out_grids) / self.time_block

    def hbm_bytes_per_step(self, itemsize: int = 4) -> float:
        """Modeled HBM bytes moved per time step by the kernel stage: every
        block fetches one expanded-halo window per operand grid (plus the
        block-sized destination operands when double-buffered) and writes
        one ``B`` block per output, amortized over ``time_block`` steps.

        Like ``TRAFFIC_COUNT`` this models the steady-state kernel stage
        only: one-time layout-stage costs per fusion window — the
        ``to_padded`` pads and the ``make_spares`` copies — are excluded
        (they amortize over the window length, which the plan does not
        know)."""
        nblocks = math.prod(self.nb)
        read = sum(math.prod(self.B[ax] + 2 * self.wf[g][ax]
                             for ax in range(self.ndim))
                   for g in self.opnd_grids)
        read += self._dest_fetches * math.prod(self.B)
        write = len(self.step_out_grids) * math.prod(self.B)
        return nblocks * (read + write) * itemsize / self.time_block

    def layout_bytes_per_window(self, itemsize: int = 4) -> float:
        """Modeled HBM bytes of the one-time per-fusion-window costs that
        ``hbm_bytes_per_step`` amortizes away: the ``to_padded`` layout
        stage (read each operand's layout-halo'd window, write its padded
        buffer), the ``make_spares`` double-buffer copies when temporally
        blocked (read + write one padded buffer per advanced grid), and
        the ``from_padded`` write-back of every touched grid's interior at
        the window boundary.  The cost model charges this once per window,
        which is why larger ``fuse_steps`` predict cheaper on this path."""
        padded = math.prod(self.padded_shape)
        total = 0.0
        for g in self.opnd_grids:
            total += math.prod(self.R[ax] + 2 * self.hw[g][ax]
                               for ax in range(self.ndim)) + padded
        if self.time_block > 1:
            total += 2 * padded * len(self.step_out_grids)
        total += 2 * math.prod(self.R) * len(self.touched)
        return total * itemsize

    def count_window(self, steps: int, batch: int = 1) -> None:
        """Accumulate modeled traffic for a fused window of ``steps`` time
        steps into ``TRAFFIC_COUNT`` (windows of ``time_block`` plus a
        remainder of single steps, mirroring the engine's decomposition).
        Remainder steps run through the single-step plan, which aliases in
        place and fetches no destination blocks.  With ``batch=B`` (the
        vmapped scenario axis — an extra leading grid dimension of the same
        ``pallas_call``) every grid's traffic scales by B; modeled ``steps``
        stay per-scenario time steps."""
        k = self.time_block
        m, r = divmod(int(steps), k)
        b = max(1, int(batch))
        TRAFFIC_COUNT["grid_reads"] += b * (
            m * (len(self.opnd_grids) + self._dest_fetches)
            + r * len(self.opnd_grids))
        TRAFFIC_COUNT["grid_writes"] += b * (m * len(self.step_out_grids)
                                             + r * len(self.out_grids))
        TRAFFIC_COUNT["steps"] += int(steps)

    # -- layout stage ------------------------------------------------------
    def to_padded(self, arrays: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        B, nb, R, ndim = self.B, self.nb, self.R, self.ndim
        padded = {}
        for g in self.opnd_grids:
            arr = arrays[g]
            ha, w = self.halos[g], self.hw[g]
            sl = tuple(slice(ha[ax] - w[ax], ha[ax] + R[ax] + w[ax])
                       for ax in range(ndim))
            W = arr[sl]
            pads = []
            for ax in range(ndim):
                before = B[ax] - w[ax]
                total = (nb[ax] + 2) * B[ax]
                pads.append((before, total - before - W.shape[ax]))
            padded[g] = jnp.pad(W, pads)
            PAD_COUNT[g] += 1
            PAD_COUNT["total"] += 1
        return padded

    # -- kernel stage ------------------------------------------------------
    def _call_for(self, dtype):
        key = jnp.dtype(dtype).name
        call = self._calls.get(key)
        if call is None:
            spec = dict(B=self.B, gh=self.gh, ndim=self.ndim, R=self.R,
                        opnd_index={g: i for i, g in
                                    enumerate(self.opnd_grids)},
                        scal_names=self.scal_names,
                        out_grids=self.out_grids, in_grids=self.in_grids,
                        wf=self.wf, hvec=self.hvec,
                        step_out_grids=self.step_out_grids,
                        dtype=dtype)
            if self.time_block > 1:
                body = _make_body_temporal(self.kernel, self.info, spec,
                                           template=self.template,
                                           mem_type=self.mem_type,
                                           time_block=self.time_block,
                                           swap=self.swap)
            else:
                body = _make_body_fused(self.kernel, self.info, spec,
                                        template=self.template,
                                        mem_type=self.mem_type)
            call = pl.pallas_call(
                body,
                grid=self.nb,
                in_specs=self._in_specs,
                out_specs=self._out_specs,
                out_shape=[jax.ShapeDtypeStruct(self.padded_shape, dtype)
                           for _ in self.step_out_grids],
                input_output_aliases=self._aliases,
                interpret=self.backend.interpret,
                name=(f"stencil_{self.kernel.name}_{self.template}"
                      f"_fused_step_k{self.time_block}"),
                compiler_params=_CompilerParams(
                    dimension_semantics=("arbitrary",) * self.ndim),
            )
            self._calls[key] = call
        return call

    def make_spares(self, padded: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Initial destination buffers for the double-buffered
        ``time_block>1`` kernel stage.  The kernel writes only interior
        blocks; the surrounding ring blocks (zero padding + grid halo) are
        taken over from the destination, so each spare starts as a copy of
        its grid's current buffer (identical ring — halo cells never change
        across steps)."""
        return {g: jnp.copy(padded[g]) for g in self.step_out_grids}

    def step(self, padded: Dict[str, jnp.ndarray],
             scalars: Dict[str, jnp.ndarray],
             spares: Optional[Dict[str, jnp.ndarray]] = None
             ) -> Dict[str, jnp.ndarray]:
        """One kernel invocation entirely in padded layout (jittable):
        one time step when ``time_block`` is 1, else ``time_block`` leapfrog
        steps with both swap buffers advanced.  With ``time_block>1`` the
        caller must pass ``spares`` — one destination buffer per
        ``step_out_grids`` entry (``make_spares``); outputs land in the
        spares' memory so the read buffers stay intact for the whole
        sequential device grid, and the buffers just read become the next
        invocation's spares.  Buffer↔name bindings are untouched; the
        caller applies the leapfrog rotation parity (``time_block``
        rotations) to the names."""
        dtype = padded[self.out_grids[0]].dtype
        ops = [padded[g] for g in self.opnd_grids]
        if self.time_block > 1:
            if spares is None:
                raise ValueError(
                    "time_block > 1 kernel stage is double-buffered: pass "
                    "spares= destination buffers (plan.make_spares)")
            ops += [spares[g] for g in self.step_out_grids]
        ops += [jnp.asarray(scalars[n], jnp.float32).reshape(1, 1)
                for n in self.scal_names]
        outs = self._call_for(dtype)(*ops)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        new = dict(padded)
        for g, O in zip(self.step_out_grids, outs):
            new[g] = O
        return new

    # -- boundary stage ----------------------------------------------------
    def from_padded(self, padded: Dict[str, jnp.ndarray],
                    arrays: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        """Merge padded interiors back into the full (grid-halo'd) arrays."""
        B, R, ndim = self.B, self.R, self.ndim
        result = dict(arrays)
        blk = tuple(slice(B[ax], B[ax] + R[ax]) for ax in range(ndim))
        for g in self.touched:
            ha = self.halos[g]
            idx = tuple(slice(ha[ax], ha[ax] + R[ax]) for ax in range(ndim))
            result[g] = result[g].at[idx].set(padded[g][blk])
        return result


def plan_pallas(kernel: ir.StencilIR,
                halos: Dict[str, Tuple[int, ...]],
                interior_shape: Tuple[int, ...],
                backend,
                swap: Optional[Tuple[str, str]] = None) -> PallasPlan:
    """Build the split (layout / per-step kernel) lowering used by the
    fused time-loop engine (``repro.core.timeloop``)."""
    return PallasPlan(kernel, halos, interior_shape, backend, swap=swap)
