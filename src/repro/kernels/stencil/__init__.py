from . import codegen, ops, ref  # noqa: F401
