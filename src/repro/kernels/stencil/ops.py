"""jit'd public wrappers around the generated Pallas stencil kernels.

``stencil_apply`` is the standalone array-level API (used by the LM
substrate, e.g. the conv1d kernel); the DSL's ``st.map`` goes through
``codegen.lower_pallas`` directly.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dsl as st

from . import codegen


def stencil_apply(kernel: "st.Kernel",
                  arrays: Dict[str, jnp.ndarray],
                  scalars: Optional[Mapping[str, jnp.ndarray]] = None,
                  *,
                  halos: Optional[Mapping[str, Tuple[int, ...]]] = None,
                  template: str = "gmem",
                  block: Optional[Tuple[int, ...]] = None,
                  mem_type: Optional[str] = None,
                  interpret: bool = True,
                  region=None) -> Dict[str, jnp.ndarray]:
    """Apply a ``@st.kernel`` to raw halo-padded arrays.

    ``arrays`` maps grid-param name → array whose shape is
    interior + 2*halo per axis.  Returns the dict with outputs updated on
    the interior (or ``region``).
    """
    k_ir = kernel.ir
    if halos is None:
        h = kernel.info.halo
        halos = {g: h for g in k_ir.grid_params}
    some = next(iter(arrays.values()))
    g0 = k_ir.grid_params[0]
    interior = tuple(s - 2 * hh for s, hh in zip(arrays[g0].shape, halos[g0]))
    backend = st.pallas(template=template, block=block, mem_type=mem_type,
                        interpret=interpret)
    fn = codegen.lower_pallas(k_ir, dict(halos), interior, region, backend)
    return jax.jit(fn)(dict(arrays), dict(scalars or {}))


def stencil_timeloop(kernel: "st.Kernel",
                     arrays: Dict[str, jnp.ndarray],
                     steps: int,
                     *,
                     swap: Tuple[str, str],
                     scalars: Optional[Mapping[str, jnp.ndarray]] = None,
                     halos: Optional[Mapping[str, Tuple[int, ...]]] = None,
                     template: str = "gmem",
                     block: Optional[Tuple[int, ...]] = None,
                     mem_type: Optional[str] = None,
                     interpret: bool = True,
                     fuse_steps: Optional[int] = None,
                     time_block: int = 1,
                     batch: int = 0) -> Dict[str, jnp.ndarray]:
    """Fused time stepping on raw halo-padded arrays (the array-level twin
    of ``st.timeloop``): ``steps`` applications + leapfrog rotation of the
    ``swap`` pair, executed on the persistent block-padded layout with one
    halo pad per grid per fusion window (``fuse_steps``, default: fully
    fused).  ``time_block=k`` advances k steps per kernel invocation with
    expanded k·h halos (in-kernel temporal blocking).  Returns the final
    arrays under the name-rotation convention (the newest field ends up
    under the *read* grid's name after each swap, exactly like a
    ``(u.data, v.data) = (v.data, u.data)`` loop).  ``batch=B`` advances B
    scenarios (arrays carry a leading scenario axis) in one program.
    """
    from repro.core import timeloop as _tl

    k_ir = kernel.ir
    if halos is None:
        h = kernel.info.halo
        halos = {g: h for g in k_ir.grid_params}
    g0 = k_ir.grid_params[0]
    spatial = arrays[g0].shape[1:] if batch else arrays[g0].shape
    interior = tuple(s - 2 * hh for s, hh in zip(spatial, halos[g0]))
    backend = st.pallas(template=template, block=block, mem_type=mem_type,
                        interpret=interpret, time_block=time_block)
    return _tl.run_timeloop(k_ir, dict(arrays), dict(scalars or {}), steps,
                            halos=dict(halos), interior_shape=interior,
                            backend=backend, swap=swap,
                            fuse_steps=fuse_steps, batch=batch)
