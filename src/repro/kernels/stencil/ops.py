"""jit'd public wrappers around the generated Pallas stencil kernels.

``stencil_apply`` is the standalone array-level API (used by the LM
substrate, e.g. the conv1d kernel); the DSL's ``st.map`` goes through
``codegen.lower_pallas`` directly.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dsl as st

from . import codegen


def stencil_apply(kernel: "st.Kernel",
                  arrays: Dict[str, jnp.ndarray],
                  scalars: Optional[Mapping[str, jnp.ndarray]] = None,
                  *,
                  halos: Optional[Mapping[str, Tuple[int, ...]]] = None,
                  template: str = "gmem",
                  block: Optional[Tuple[int, ...]] = None,
                  mem_type: Optional[str] = None,
                  interpret: bool = True,
                  region=None) -> Dict[str, jnp.ndarray]:
    """Apply a ``@st.kernel`` to raw halo-padded arrays.

    ``arrays`` maps grid-param name → array whose shape is
    interior + 2*halo per axis.  Returns the dict with outputs updated on
    the interior (or ``region``).
    """
    k_ir = kernel.ir
    if halos is None:
        h = kernel.info.halo
        halos = {g: h for g in k_ir.grid_params}
    some = next(iter(arrays.values()))
    g0 = k_ir.grid_params[0]
    interior = tuple(s - 2 * hh for s, hh in zip(arrays[g0].shape, halos[g0]))
    backend = st.pallas(template=template, block=block, mem_type=mem_type,
                        interpret=interpret)
    fn = codegen.lower_pallas(k_ir, dict(halos), interior, region, backend)
    return jax.jit(fn)(dict(arrays), dict(scalars or {}))
