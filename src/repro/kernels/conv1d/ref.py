"""Pure-jnp oracle for the causal depthwise conv1d kernel."""
from __future__ import annotations

import jax.numpy as jnp


def causal_conv1d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, W]; w: [cw, W] → y_t = Σ_k w[k] · x_{t-cw+1+k} (zero hist)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    return sum(xp[:, k:k + x.shape[1]] * w[k][None, None, :]
               for k in range(cw))
