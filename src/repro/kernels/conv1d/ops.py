"""jit'd wrapper for the causal conv1d kernel."""
from __future__ import annotations

from functools import partial

import jax

from .conv1d import causal_conv1d_pallas


@partial(jax.jit, static_argnames=("interpret",))
def causal_conv1d(x, w, interpret: bool = True):
    return causal_conv1d_pallas(x, w, interpret=interpret)
