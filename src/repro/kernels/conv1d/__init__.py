from . import conv1d, ops, ref  # noqa: F401
