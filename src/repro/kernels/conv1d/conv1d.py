"""Causal depthwise 1-D convolution Pallas kernel (temporal stencil).

Used by the Griffin/RecurrentGemma recurrent block and demonstrating the
Whisper conv-stem op.  Structure mirrors the stencil codegen's
neighbor-block scheme: the time axis is blocked and each output block reads
its own block plus the previous one (causal halo = conv_width − 1).
Weights are runtime values (learned), which is why this kernel is built
directly rather than through the literal-coefficient DSL.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _kernel(prev_ref, cur_ref, w_ref, out_ref, *, cw: int, bt: int):
    prev = prev_ref[...]
    cur = cur_ref[...]
    w = w_ref[...]
    hist = prev[:, bt - (cw - 1):] if cw > 1 else cur[:, :0]
    x = jnp.concatenate([hist, cur], axis=1) if cw > 1 else cur
    acc = jnp.zeros_like(cur)
    for k in range(cw):
        acc = acc + x[:, k:k + bt] * w[k][None, None, :]
    out_ref[...] = acc


def causal_conv1d_pallas(x: jnp.ndarray, w: jnp.ndarray, *,
                         block_t: int = 128, block_w: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """x: [B, T, W]; w: [cw, W] → causal depthwise conv, same length
    (zero history)."""
    B, T, W = x.shape
    cw = w.shape[0]
    bt = min(block_t, -(-T // 8) * 8)
    bt = max(bt, cw - 1, 1)  # causal halo must fit in one previous block
    bw = min(block_w, W)
    nT = -(-T // bt)
    nW = -(-W // bw)
    Tp, Wp = nT * bt, nW * bw
    xp = jnp.pad(x, ((0, 0), (bt, Tp - T), (0, Wp - W)))  # 1 halo block front
    wp = jnp.pad(w, ((0, 0), (0, Wp - W)))

    out = pl.pallas_call(
        functools.partial(_kernel, cw=cw, bt=bt),
        grid=(B, nT, nW),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda b, t, c: (b, t, c)),      # prev
            pl.BlockSpec((1, bt, bw), lambda b, t, c: (b, t + 1, c)),  # cur
            pl.BlockSpec((cw, bw), lambda b, t, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, bt, bw), lambda b, t, c: (b, t, c)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, Wp), x.dtype),
        interpret=interpret,
        name="causal_conv1d",
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(xp, xp, wp)   # padded array feeds both the prev- and cur-block refs
    return out[:, :T, :W]
