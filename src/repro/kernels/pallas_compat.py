"""Version-tolerance shims for the Pallas TPU API.

The installed JAX exposes the TPU compiler-params dataclass under either
``pltpu.CompilerParams`` (newer) or ``pltpu.TPUCompilerParams`` (older);
every Pallas kernel in this repo goes through this one lookup.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)
