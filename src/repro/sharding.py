"""Logical-axis sharding rules for the LM substrate (MaxText-style).

Every parameter leaf is assigned a tuple of *logical* axis names from its
pytree path + shape; ``LOGICAL_RULES`` maps logical names to mesh axes.
The same rules drive single-pod (data, model) and multi-pod
(pod, data, model) meshes — batch extends over ('pod', 'data'), parameters
are 2-D sharded (FSDP over 'data' × TP over 'model') and replicated across
pods (gradient all-reduce crosses the DCN once per step).

Resolution is divisibility-aware: a logical axis only binds to a mesh axis
if the dimension is divisible by the axis size (so kv_heads=8 on a 16-wide
'model' axis replicates instead of padding, while d_ff=14336 shards).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "logical_axes_for", "resolve", "param_shardings", "batch_shardings",
    "cache_shardings", "scalar_sharding", "LOGICAL_RULES",
    "use_activation_mesh", "constrain",
]

# logical axis → preferred mesh axis (None = replicate)
LOGICAL_RULES: Dict[str, Optional[str]] = {
    "batch": "data",          # extended to ('pod','data') on multi-pod meshes
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "rnn": "model",
    "experts": None,          # E=8 < axis 16: TP d_ff instead (see DESIGN §6)
    "embed": "data",          # FSDP / ZeRO param+optimizer sharding
    "vocab_table": "data",    # tok table rows (gather-friendly)
    "kv_seq": "model",        # KV-cache seq dim when kv_heads can't shard
    "head_dim": None,
    "layers": None,
    "seq": None,
    "enc_seq": None,
    "conv_w": None,
}


# --------------------------------------------------------------------------
# activation sharding constraints (GSPMD hygiene)
# --------------------------------------------------------------------------
# Model code calls ``constrain(x, "batch", "seq", "embed")`` at layer
# boundaries; without these hints GSPMD resolves FSDP-sharded weight
# contractions as partial-sum + all-reduce, replicating the batch dim of
# huge activations (observed: unsharded [B, C, V] loss logits).  The mesh is
# supplied by the launcher via ``use_activation_mesh`` at trace time; when
# unset (single-device smoke tests) constraints are no-ops.

_ACT = threading.local()


@contextlib.contextmanager
def use_activation_mesh(mesh: Optional[Mesh]):
    prev = getattr(_ACT, "mesh", None)
    _ACT.mesh = mesh
    try:
        yield
    finally:
        _ACT.mesh = prev


def activation_mesh() -> Optional[Mesh]:
    return getattr(_ACT, "mesh", None)


def constrain(x, *axes: Optional[str]):
    """Apply a logical-axis sharding constraint to an activation (no-op
    without an active activation mesh)."""
    mesh = activation_mesh()
    if mesh is None:
        return x
    if len(axes) < x.ndim:
        axes = tuple(axes) + (None,) * (x.ndim - len(axes))
    spec = resolve(axes[:x.ndim], x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_like_params(tree, cfg, param_shapes_tree=None):
    """Pin a params-shaped tree (e.g. the f32 gradient accumulator) to the
    parameters' own 2-D sharding.  Without this the scan-carried grad
    accumulator initializes from unsharded zeros and GSPMD may keep it
    replicated — turning the per-microbatch gradient reduction into
    full-size all-reduces instead of reduce-scatters."""
    mesh = activation_mesh()
    if mesh is None:
        return tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        axes = logical_axes_for(_path_str(path), leaf.shape, cfg)
        spec = resolve(axes, leaf.shape, mesh)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def kv_cache_mode(cfg) -> Optional[str]:
    """How the decode KV cache shards on the active mesh: 'heads' when
    kv_heads divides the model axis, else 'seq' (cache sequence dim over
    'model'; attention becomes partial-softmax + tiny all-reduces).
    None without a mesh."""
    mesh = activation_mesh()
    if mesh is None or mesh.shape.get("model", 1) <= 1:
        return None
    return "heads" if cfg.n_kv_heads % mesh.shape["model"] == 0 else "seq"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for(path_str: str, shape: Tuple[int, ...],
                     cfg: ModelConfig) -> Tuple[Optional[str], ...]:
    """Logical axis names for one parameter leaf."""
    nd = len(shape)
    name = path_str.rsplit("/", 1)[-1]
    # scan-stacked param groups carry a leading layer/cycle dim
    stacked = any(seg in path_str for seg in
                  ("layers/", "enc_layers/", "dec_layers/", "cycles/")) \
        or path_str.startswith(("layers", "enc_layers", "dec_layers",
                                "cycles"))
    lead: Tuple[Optional[str], ...] = ("layers",) if stacked else ()
    core = shape[1:] if stacked else shape
    cnd = len(core)

    def out(*axes):
        assert len(axes) == cnd, (path_str, shape, axes)
        return lead + tuple(axes)

    # -- embeddings ---------------------------------------------------------
    # tok: gather-friendly — vocab over 'data' only (XLA lowers a gather
    # from a vocab-sharded table to local-gather+mask+all-reduce, keeping
    # the batch dim sharded; 2-D table sharding forces involuntary full
    # rematerialization through the SPMD partitioner)
    if "embed" in path_str and name == "tok":
        return out("vocab_table", "embed")
    if "embed" in path_str and name == "out":
        return out("embed", "vocab")

    # -- MoE (before generic attention/MLP names — moe params share them) ---
    if "moe" in path_str:
        if name == "router":
            return out("embed", "experts")
        if name in ("wg", "wu") and cnd == 3:
            return out("experts", "embed", "ff")
        if name == "wo" and cnd == 3:
            return out("experts", "ff", "embed")

    # -- attention ----------------------------------------------------------
    if name == "wq" and cnd == 3:
        return out("embed", "heads", "head_dim")
    if name in ("wk", "wv") and cnd == 3:
        return out("embed", "kv_heads", "head_dim")
    if name == "wo" and cnd == 3:                  # attn out [H, hd, D]
        return out("heads", "head_dim", "embed")
    if name == "wo_gate" and cnd == 3:             # xlstm output gate
        return out("embed", "heads", "head_dim")
    if name in ("wz", "wi", "wf") and cnd == 3:    # xlstm projections
        return out("embed", "heads", "head_dim")
    if name in ("wi", "wf") and cnd == 2 and any(
            s in path_str for s in ("blocks", "cycles", "tail")):
        return out("embed", "heads")               # mlstm scalar gates

    # -- dense MLP -----------------------------------------------------------
    if name in ("wg", "wu", "wi") and cnd == 2:
        return out("embed", "ff")
    if name == "wo" and cnd == 2:
        return out("ff", "embed")

    # -- griffin recurrent block ---------------------------------------------
    if name in ("w_gate", "w_x") and cnd == 2:
        return out("embed", "rnn")
    if name == "w_out" and cnd == 2:
        return out("rnn", "embed")
    if name in ("wa",) and cnd == 2:
        return out("embed", "rnn")                 # [w, w]: FSDP × TP
    if name == "conv_w":
        return out("conv_w", "rnn")

    # -- 1-D / small leaves ---------------------------------------------------
    if cnd == 0:
        return out()
    if cnd == 1:
        # gate biases / norm scales over the rnn or ff width
        if name in ("conv_b", "ba", "bi", "lam"):
            return out("rnn")
        return out(None)
    if cnd == 2 and name == "bf":
        return out("heads", "head_dim")

    # -- fallback: shard the two largest trailing dims (data × model) --------
    if cnd >= 2:
        return lead + (None,) * (cnd - 2) + ("embed", "ff")
    return lead + (None,) * cnd


def _mesh_axes_for(logical: Optional[str], mesh: Mesh):
    """Resolve one logical axis to mesh axis (or tuple for batch)."""
    if logical is None:
        return None
    if logical == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        return axes if axes else None
    m = LOGICAL_RULES.get(logical)
    if m is None or m not in mesh.shape:
        return None
    return m


def _axis_size(mesh: Mesh, m) -> int:
    if m is None:
        return 1
    if isinstance(m, tuple):
        return int(np.prod([mesh.shape[a] for a in m]))
    return mesh.shape[m]


def resolve(axes: Sequence[Optional[str]], shape: Tuple[int, ...],
            mesh: Mesh) -> P:
    """Logical axes → PartitionSpec, dropping non-divisible bindings and
    duplicate mesh-axis uses (first binding wins)."""
    spec = []
    used = set()
    for dim, logical in zip(shape, axes):
        m = _mesh_axes_for(logical, mesh)
        if m is None:
            spec.append(None)
            continue
        flat = m if isinstance(m, tuple) else (m,)
        if used & set(flat):
            spec.append(None)
            continue
        size = _axis_size(mesh, m)
        if size <= 1 or dim % size != 0:
            spec.append(None)
            continue
        used.update(flat)
        spec.append(m)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, param_shapes):
    """NamedSharding tree matching ``param_shapes`` (a ShapeDtypeStruct
    tree from ``models.api.param_shapes``)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        axes = logical_axes_for(_path_str(path), leaf.shape, cfg)
        out.append(NamedSharding(mesh, resolve(axes, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs):
    """Inputs: batch dim over ('pod','data') when divisible; the rest
    replicated.  Works for train/prefill batches (dicts of [B, ...])."""
    def one(leaf):
        spec = resolve(("batch",) + (None,) * (len(leaf.shape) - 1),
                       leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, specs)


def _kv_cache_axes(cfg: ModelConfig, mesh: Mesh, lead: Tuple):
    """KV buffers [.., B, S, K, hd]: shard kv_heads over 'model' when
    divisible, else fall back to sharding the cache's *sequence* dim over
    'model' (decode attention over seq-sharded KV lowers to partial
    softmax + tiny all-reduces — the memory win dominates at 32k+)."""
    K = cfg.n_kv_heads
    msize = mesh.shape.get("model", 1)
    if msize > 1 and K % msize == 0:
        return lead + ("batch", "seq", "kv_heads", "head_dim")
    return lead + ("batch", "kv_seq", None, "head_dim")


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_spec):
    """Decode caches: batch over ('pod','data'); KV buffers additionally
    over 'model' (kv_heads when divisible, else the sequence dim);
    recurrent states over 'model' on their width dims.

    Leaf layouts (lead = stacked layer/cycle dim where present):
      [(L,) B, S, K, hd]   transformer / whisper / griffin-attn KV
      [(L,) B, cw-1, W]    griffin conv state        (W = rnn width)
      [(L,) B, W]          griffin RG-LRU state
      [(L,) B, H, dk(,dv)] xlstm mLSTM/sLSTM states
      scalar pos
    """
    def one(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        ps = _path_str(path)
        name = ps.rsplit("/", 1)[-1]
        stacked = ("cycles/" in ps or ps.startswith("cycles")
                   or (name in ("k", "v", "xk", "xv") and nd == 5))
        lead = ("layers",) if stacked else ()
        core = nd - len(lead)

        if name in ("k", "v", "xk", "xv"):               # KV buffers
            axes = _kv_cache_axes(cfg, mesh, lead)
        elif name == "conv":                             # [.., B, cw-1, W]
            axes = lead + ("batch", None, "rnn")
        elif name == "h":                                # [.., B, W]
            axes = lead + ("batch", "rnn")
        elif core == 4 and shape[-1] == shape[-2]:       # mlstm C [B,H,d,d]
            axes = lead + ("batch", "heads", None, None)
        elif core == 3:                                  # xlstm n / sLSTM
            axes = lead + ("batch", "heads", "head_dim")
        elif core == 2:                                  # xlstm m [B,H]
            axes = lead + ("batch", "heads")
        else:
            axes = lead + ("batch",) + (None,) * (core - 1)
        axes = tuple(axes)[:nd]
        return NamedSharding(mesh, resolve(axes, shape, mesh))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_spec)
    out = [one(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
