"""Hand-rolled AdamW + global-norm clipping + warmup-cosine schedule.

No optax: the optimizer state is a plain pytree shaped like the params, so
it inherits the params' 2-D (FSDP × TP) sharding — that *is* the ZeRO-style
optimizer-state sharding (each chip owns the m/v slices of its param
shards).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> Dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros)}


def schedule(c: OptConfig, step) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = c.lr * step / max(c.warmup_steps, 1)
    frac = jnp.clip((step - c.warmup_steps)
                    / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.min_lr_ratio * c.lr + (1 - c.min_lr_ratio) * c.lr \
        * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < c.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(jnp.asarray(l).astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    if not leaves:
        # empty tree has norm 0 (jnp.stack([]) would raise)
        return jnp.float32(0.0)
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _is_matrix(p) -> bool:
    return jnp.ndim(p) >= 2


def apply(c: OptConfig, params, grads, opt_state, step) -> Tuple[Dict, Dict, Dict]:
    """→ (new_params, new_opt_state, metrics).  step is 0-based.

    Weight decay targets matmul weights inside a parameter *tree*; a bare
    array passed as the whole params (e.g. the velocity grid in
    ``examples/fwi.py``) is a physical field, not a network weight, and is
    never decayed — regularize such inversions explicitly in the loss.
    """
    bare = jax.tree_util.treedef_is_leaf(jax.tree.structure(params))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, c.clip_norm)

    t = step.astype(jnp.float32) + 1.0
    lr = schedule(c, step)
    bc1 = 1.0 - c.b1 ** t
    bc2 = 1.0 - c.b2 ** t

    m2 = jax.tree.map(lambda m, g: c.b1 * m + (1 - c.b1) * g,
                      opt_state["m"], grads)
    v2 = jax.tree.map(lambda v, g: c.b2 * v + (1 - c.b2) * g * g,
                      opt_state["v"], grads)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + c.eps)
        if c.weight_decay and not bare and _is_matrix(p):
            u = u + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m2, v2)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": m2, "v": v2}, metrics


# -- gradient compression (beyond-paper: cheap DCN all-reduce) ---------------
def compress_int8(tree):
    """Per-leaf symmetric int8 quantization: (q, scale).  Used to shrink
    cross-pod (DCN) gradient all-reduce traffic 4× vs f32; validated
    convergence-neutral on the smoke model in tests/test_train.py."""
    def one(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}
    return jax.tree.map(one, tree)


def decompress_int8(ctree):
    return jax.tree.map(
        lambda c: c["q"].astype(jnp.float32) * c["scale"],
        ctree, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
