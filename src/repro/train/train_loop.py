"""Sharded training step: microbatched grad accumulation + AdamW.

``make_train_step(cfg, ...)`` returns a jit'd (or AOT-lowerable)
``train_step(state, batch) -> (state, metrics)`` with explicit
in/out shardings:

* params / optimizer state — 2-D sharded (FSDP 'data' × TP 'model'),
  pod-replicated (multi-pod: gradient all-reduce crosses DCN once/step).
* batch — sharded over ('pod', 'data') on the leading dim.
* microbatching — ``lax.scan`` over ``n_microbatches`` slices of the global
  batch, accumulating f32 grads; activation peak is one microbatch
  (the standard activation-memory lever at 4k×256 scale).

State donation keeps params in place across steps.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding
from repro.configs.base import ModelConfig
from repro.models import api
from . import optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optimizer.OptConfig = dataclasses.field(
        default_factory=optimizer.OptConfig)
    n_microbatches: int = 1


def init_state(cfg: ModelConfig, key=None) -> Dict:
    params = api.init_params(cfg, key)
    return {"params": params,
            "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(cfg: ModelConfig) -> Dict:
    pshapes = api.param_shapes(cfg)
    return {"params": pshapes,
            "opt": {"m": jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                        pshapes),
                    "v": jax.tree.map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                        pshapes)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_shardings(cfg: ModelConfig, mesh: Mesh) -> Dict:
    pshapes = api.param_shapes(cfg)
    ps = sharding.param_shardings(cfg, mesh, pshapes)
    return {"params": ps,
            "opt": {"m": jax.tree.map(lambda s: s, ps),
                    "v": jax.tree.map(lambda s: s, ps)},
            "step": sharding.scalar_sharding(mesh)}


def _split_microbatches(batch: Dict, n: int) -> Dict:
    def resh(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(resh, batch)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Pure ``train_step(state, batch)`` (jit it yourself, or use
    ``compile_train_step`` for the sharded AOT path)."""

    def loss_of(params, mb):
        loss, metrics = api.loss_fn(cfg, params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        n = tc.n_microbatches
        if n > 1:
            mbs = _split_microbatches(batch, n)
            g0 = sharding.constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params), cfg)

            def mb_step(acc, mb):
                g_acc, loss_acc = acc
                (loss, _m), g = grad_fn(params, mb)
                # per-microbatch grads pinned to the params' sharding so
                # the data-axis reduction lowers to reduce-scatter, not a
                # replicated all-reduce
                g = sharding.constrain_like_params(g, cfg)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (gsum, loss_sum), _ = lax.scan(
                mb_step, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = loss_sum / n
        else:
            (loss, _m), grads = grad_fn(params, batch)
            grads = sharding.constrain_like_params(grads, cfg)

        new_params, new_opt, om = optimizer.apply(
            tc.opt, params, grads, state["opt"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return new_state, metrics

    return train_step


def compile_train_step(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                       batch_specs: Dict, donate: bool = True):
    """AOT path used by the dry-run and the launcher: returns
    (lowered, jitted) against abstract state/batch."""
    step_fn = make_train_step(cfg, tc)
    st_shard = state_shardings(cfg, mesh)
    b_shard = sharding.batch_shardings(cfg, mesh, batch_specs)
    metrics_shard = {"loss": sharding.scalar_sharding(mesh),
                     "grad_norm": sharding.scalar_sharding(mesh),
                     "lr": sharding.scalar_sharding(mesh)}
    jitted = jax.jit(
        step_fn,
        in_shardings=(st_shard, b_shard),
        out_shardings=(st_shard, metrics_shard),
        donate_argnums=(0,) if donate else ())
    with sharding.use_activation_mesh(mesh):
        lowered = jitted.lower(state_specs(cfg), batch_specs)
    return lowered, jitted
