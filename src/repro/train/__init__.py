"""Training substrate: optimizer, train loop, checkpointing, data pipeline,
fault tolerance."""
