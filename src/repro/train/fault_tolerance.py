"""Fault tolerance: checkpoint-restart driver, straggler watchdog,
preemption handling, failure injection for tests.

Posture at 1000+ nodes (synchronous SPMD):

* **node failure** → the job dies (collectives time out); the *driver*
  restarts it from the latest atomic checkpoint.  `run_with_restarts`
  is that driver loop, in-process.  Determinism of the data pipeline
  (counter-based; see data.py) + checkpointed (params, opt, step) make the
  restart exactly replay the lost steps.
* **stragglers** → per-step wall-time watchdog; a step slower than
  ``threshold × median`` is logged as a straggler event.  On a real
  cluster the event feeds the scheduler's eviction policy (replace node,
  restart from checkpoint); here it is surfaced to the caller.
* **preemption** → SIGTERM handler requests a final checkpoint at the next
  step boundary, then exits cleanly.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

from . import checkpoint


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float


class Watchdog:
    """Tracks per-step wall time; flags steps slower than
    ``threshold ×`` the running median."""

    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, seconds: float) -> Optional[StragglerEvent]:
        med = (sorted(self.times)[len(self.times) // 2]
               if self.times else seconds)
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5 and seconds > self.threshold * med:
            ev = StragglerEvent(step, seconds, med)
            self.events.append(ev)
            return ev
        return None


class PreemptionHandler:
    """SIGTERM → request a clean stop at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def install(self):
        def handler(signum, frame):
            self.requested = True
        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


class FailureInjector:
    """Deterministic failure injection for tests: raises RuntimeError at
    the given steps (once each)."""

    def __init__(self, fail_at_steps):
        self.fail_at = set(fail_at_steps)

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")


def run_with_restarts(*,
                      init_fn: Callable[[], Dict],
                      step_fn: Callable[[Dict, int], Dict],
                      n_steps: int,
                      ckpt_dir: str,
                      ckpt_every: int = 10,
                      max_failures: int = 3,
                      shardings=None,
                      watchdog: Optional[Watchdog] = None,
                      injector: Optional[FailureInjector] = None,
                      on_metrics: Optional[Callable] = None) -> Dict:
    """Checkpoint-restart training driver.

    ``step_fn(state, step) -> state`` must advance ``state['step']``.
    Restarts resume from the latest complete checkpoint; the deterministic
    data pipeline replays the stream exactly.
    """
    failures = 0
    preempt = PreemptionHandler().install()
    try:
        while True:
            try:
                latest = checkpoint.latest_step(ckpt_dir)
                if latest is not None:
                    template = init_fn()
                    state = checkpoint.restore(ckpt_dir, template,
                                               shardings=shardings)
                    start = latest
                else:
                    state = init_fn()
                    start = 0
                for step in range(start, n_steps):
                    if injector is not None:
                        injector.maybe_fail(step)
                    t0 = time.perf_counter()
                    state = step_fn(state, step)
                    dt = time.perf_counter() - t0
                    if watchdog is not None:
                        watchdog.observe(step, dt)
                    if on_metrics is not None:
                        on_metrics(step, state, dt)
                    done = step + 1
                    if done % ckpt_every == 0 or done == n_steps \
                            or preempt.requested:
                        checkpoint.save(ckpt_dir, done, state)
                    if preempt.requested:
                        return state
                return state
            except (RuntimeError,) as e:
                failures += 1
                if failures > max_failures:
                    raise
                # driver restart: fall through to restore-from-latest
                continue
    finally:
        preempt.uninstall()
