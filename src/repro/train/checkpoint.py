"""Sharded, atomic, elastic checkpointing.

Layout (one directory per step)::

    <dir>/step_00000420/
        manifest.json        # treedef, shapes, dtypes, step, write-complete
        leaf_00000.npy ...   # one file per pytree leaf

* **atomic** — written to ``step_XXXX.tmp/`` then ``os.rename``d; a crash
  mid-write never corrupts the latest checkpoint.
* **elastic** — leaves are saved as *full* (unsharded) arrays and restored
  with ``jax.device_put(leaf, sharding)`` against whatever mesh the resumed
  job has, so a restart may use a different data-parallel size (validated
  in tests/test_train.py::test_elastic_reshard).  On a real multi-host pod
  each host writes its address-able shards and the manifest carries the
  global shape — the single-process layout here is the degenerate case.
* **async** — ``save(..., blocking=False)`` hands the write to a daemon
  thread (double-buffered; at most one outstanding write).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Dict, Optional

import jax
import numpy as np

_PENDING: Optional[threading.Thread] = None


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(ckpt_dir: str, step: int, state, blocking: bool = True) -> str:
    """Write ``state`` (a pytree of arrays) for ``step``; returns path."""
    global _PENDING
    if _PENDING is not None:
        _PENDING.join()            # one outstanding async write max
        _PENDING = None

    leaves, treedef = jax.tree_util.tree_flatten(state)
    # snapshot to host before handing off (donation-safe)
    host_leaves = [np.asarray(l) for l in leaves]

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        for i, l in enumerate(host_leaves):
            np.save(os.path.join(tmp, _leaf_name(i)), l)
        manifest = {
            "step": int(step),
            "n_leaves": len(host_leaves),
            "treedef": str(treedef),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "complete": True,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        write()
    else:
        _PENDING = threading.Thread(target=write, daemon=True)
        _PENDING.start()
    return final


def wait():
    """Block until any async save has landed."""
    global _PENDING
    if _PENDING is not None:
        _PENDING.join()
        _PENDING = None


def steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            m = os.path.join(ckpt_dir, d, "manifest.json")
            if os.path.exists(m):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    s = steps(ckpt_dir)
    return s[-1] if s else None


def restore(ckpt_dir: str, target, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings
    for elastic placement on the *current* mesh (may differ from the mesh
    that wrote the checkpoint)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise IOError(f"incomplete checkpoint at {path}")

    t_leaves, treedef = jax.tree_util.tree_flatten(target)
    assert manifest["n_leaves"] == len(t_leaves), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs target {len(t_leaves)}"
    s_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                if shardings is not None else [None] * len(t_leaves))

    out = []
    for i, (t, s) in enumerate(zip(t_leaves, s_leaves)):
        arr = np.load(os.path.join(path, _leaf_name(i)))
        assert tuple(arr.shape) == tuple(t.shape), \
            f"leaf {i}: ckpt shape {arr.shape} vs target {t.shape}"
        arr = arr.astype(t.dtype)
        out.append(jax.device_put(arr, s) if s is not None else
                   jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def prune(ckpt_dir: str, keep: int = 3):
    for s in steps(ckpt_dir)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
