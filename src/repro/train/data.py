"""Deterministic synthetic token pipeline.

Stateless: ``batch(step)`` is a pure function of (seed, step) via the
counter-based Philox generator, so a restarted job replays the exact same
stream — this is what makes checkpoint-restart bitwise reproducible and
elastic re-sharding trivial (any host can materialize any slice).

The token stream is *learnable* (affine next-token structure + noise) so
training-loss decrease is a meaningful signal in tests and examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05     # fraction of tokens replaced with uniform noise


class SyntheticLM:
    """Markov-ish synthetic LM data: x_{t+1} = (a·x_t + c) mod V with
    occasional uniform-noise tokens.  labels = next token."""

    def __init__(self, c: DataConfig):
        self.c = c
        # odd multiplier → full-period affine map over Z_V when V is 2^k;
        # otherwise still a learnable deterministic map
        self.a = 5
        self.add = 17

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.c
        rng = np.random.Generator(np.random.Philox(key=c.seed, counter=step))
        B, S = c.global_batch, c.seq_len
        x0 = rng.integers(0, c.vocab, size=(B, 1))
        toks = [x0]
        for _ in range(S):
            toks.append((self.a * toks[-1] + self.add) % c.vocab)
        seq = np.concatenate(toks, axis=1)          # [B, S+1]
        noise_mask = rng.random((B, S + 1)) < c.noise
        noise = rng.integers(0, c.vocab, size=(B, S + 1))
        seq = np.where(noise_mask, noise, seq)
        return {"tokens": seq[:, :S].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}


def make_batch_fn(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0,
                  global_batch: Optional[int] = None,
                  seq_len: Optional[int] = None):
    """Batch generator matching ``configs.shapes.input_specs`` (including
    the stub modality frontends)."""
    B = global_batch or shape.global_batch
    S = seq_len or shape.seq_len
    if cfg.family == "audio":
        S_tok = S // 2
    elif cfg.family == "vlm":
        S_tok = S - cfg.n_prefix_tokens
    else:
        S_tok = S
    lm = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=S_tok,
                                global_batch=B, seed=seed))

    def batch(step: int) -> Dict[str, np.ndarray]:
        out = dict(lm.batch(step))
        rng = np.random.Generator(np.random.Philox(key=seed + 1,
                                                   counter=step))
        if cfg.family == "audio":
            out["frame_embeds"] = rng.standard_normal(
                (B, S // 2, cfg.d_model)).astype(np.float32)
        elif cfg.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (B, cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        return out

    return batch
